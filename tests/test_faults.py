"""Self-healing data plane: health states, retry/backoff, mid-flight write
re-placement, background re-replication, writer recovery, and the seeded
chaos harness.

The chaos tests drive live mixed traffic from several sessions while a
deterministic :class:`FaultSchedule` kills/recovers providers and injects
RPC drops/delays, then assert the interleaving-independent invariants the
paper's lock-free design must hold: zero data loss for published versions,
a monotone publish frontier, and replication-factor restoration after
repair.
"""

import threading

import numpy as np
import pytest

from repro.core import (
    Cluster,
    DataProvider,
    FaultEvent,
    FaultInjector,
    FaultSchedule,
    HealthConfig,
    MetadataDHT,
    ProviderFailed,
    ProviderManager,
    RetryPolicy,
    TrafficStats,
    VersionManager,
    page_checksum,
)
from repro.core import Federation
from repro.core.faults import DELAY, DROP, KILL, METADATA, NODE, RECOVER

PAGE = 256


class FakeClock:
    def __init__(self, start=1000.0):
        self.now = start

    def __call__(self):
        return self.now

    def advance(self, dt):
        self.now += dt


def make_manager(n=4, replication=1, **health_kw):
    clock = health_kw.pop("clock", FakeClock())
    mgr = ProviderManager(
        replication=replication,
        stats=TrafficStats(),
        health=HealthConfig(clock=clock, **health_kw),
    )
    for i in range(n):
        mgr.register(DataProvider(i))
    return mgr, clock


# ----------------------------- health machine ---------------------------------


def test_health_state_machine_live_suspect_dead():
    mgr, clock = make_manager(suspect_after=1, dead_after=3, window_seconds=10.0)
    assert mgr.health_state(0) == "live"
    mgr.note_failure(0)
    assert mgr.health_state(0) == "suspect"
    mgr.note_failure(0)
    assert mgr.health_state(0) == "suspect"
    mgr.note_failure(0)
    assert mgr.health_state(0) == "dead"
    assert mgr.dead_providers() == [0]
    # an observed success is the recovery signal
    mgr.note_success(0)
    assert mgr.health_state(0) == "live"
    assert mgr.dead_providers() == []


def test_health_failures_decay_outside_window():
    mgr, clock = make_manager(suspect_after=1, dead_after=3, window_seconds=10.0)
    mgr.note_failure(0)
    mgr.note_failure(0)
    clock.advance(11.0)  # both failures age out of the window
    assert mgr.health_state(0) == "live"
    mgr.note_failure(0)  # fresh failure alone: suspect, NOT dead
    assert mgr.health_state(0) == "suspect"
    assert mgr.dead_providers() == []


def test_on_dead_fires_exactly_once_outside_lock():
    mgr, _ = make_manager(dead_after=2)
    deaths = []
    mgr.on_dead = deaths.append
    for _ in range(5):
        mgr.note_failure(1)
    assert deaths == [1]  # once per death, not per failure
    mgr.note_success(1)
    mgr.note_failure(1)
    mgr.note_failure(1)
    assert deaths == [1, 1]  # a NEW death after recovery fires again


def test_healthy_providers_excludes_suspect_and_failed():
    mgr, _ = make_manager(suspect_after=1, dead_after=3)
    mgr.note_failure(0)
    mgr.fail_provider(1)
    healthy = {p.provider_id for p in mgr.healthy_providers()}
    assert healthy == {2, 3}


# --------------------------- placement satellites ------------------------------


def test_allocate_skips_failed_and_dead_providers():
    """Satellite regression: fresh pages must never land on a provider whose
    failure flag is set or that the health machine declared dead."""
    mgr, _ = make_manager(n=4, replication=2, dead_after=1)
    mgr.fail_provider(0)
    mgr.note_failure(3)  # dead_after=1 -> declared dead
    out = mgr.allocate(40)
    pids = {pid for primary, replicas in out for pid, _ in (primary,) + replicas}
    assert pids == {1, 2}
    # suspect providers STAY placeable (one blip must not evict a node)
    mgr2, _ = make_manager(n=2, replication=1, suspect_after=1, dead_after=3)
    mgr2.note_failure(0)
    assert mgr2.health_state(0) == "suspect"
    assert {p for (p, _), _ in mgr2.allocate(10)} == {0, 1}


def test_allocate_raises_only_when_healthy_below_replication():
    mgr, _ = make_manager(n=3, replication=2)
    mgr.fail_provider(0)
    mgr.allocate(4)  # 2 healthy of 3: still satisfiable
    mgr.fail_provider(1)
    with pytest.raises(ProviderFailed, match="1 healthy providers"):
        mgr.allocate(4)
    mgr.recover_provider(1)
    assert mgr.allocate(4)  # recovery restores placement immediately


def test_recovered_provider_resurfaces_in_placement():
    mgr, _ = make_manager(n=2, replication=1)
    mgr.fail_provider(0)
    assert {p for (p, _), _ in mgr.allocate(6)} == {1}
    mgr.recover_provider(0)
    pids = {p for (p, _), _ in mgr.allocate(8)}
    assert 0 in pids  # least-loaded now, must be discoverable again


def test_deregister_releases_load_credit():
    """Satellite: a departing provider's outstanding load credit must not
    haunt the books (it skewed every later least-loaded decision)."""
    mgr, _ = make_manager(n=2, replication=1)
    placements = mgr.allocate(10)
    held = sum(1 for (pid, _), _ in placements if pid == 0)
    assert mgr.deregister(0) == held
    assert 0 not in mgr.load_snapshot()
    # the remaining provider's credit is untouched
    assert mgr.load_snapshot()[1] == 10 - held


def test_unknown_provider_ids_raise_clear_keyerror():
    mgr, _ = make_manager(n=2)
    for op in (mgr.get_provider, mgr.fail_provider, mgr.recover_provider,
               mgr.health_state):
        with pytest.raises(KeyError, match="unknown data provider id 99"):
            op(99)


# ------------------------------ retry policy -----------------------------------


def test_retry_policy_deterministic_and_bounded():
    a = RetryPolicy(seed=7)
    delays = [a.delay(k) for k in range(5)]
    # replayable: an instance's delay stream is a pure function of
    # (seed, nonce, attempt) — pin the nonce to replay another instance's
    # exact stream (e.g. when reproducing a logged chaos run)
    replay = RetryPolicy(seed=7, nonce=a.nonce)
    assert delays == [replay.delay(k) for k in range(5)]
    assert delays[0] < delays[1] < delays[2]  # exponential growth
    for k, d in enumerate(delays):
        assert d <= a.max_delay_seconds * (1 + a.jitter)
    assert RetryPolicy(seed=8, nonce=a.nonce).delay(1) != a.delay(1)


def test_retry_policy_instances_desynchronize():
    """Satellite bugfix: two same-seed policies used to produce IDENTICAL
    jitter streams, so every concurrent client backing off from the same
    hot provider retried in lockstep — synchronized retry storms, exactly
    what jitter exists to prevent. Each instance now mixes a per-instance
    nonce into the stream, so concurrent policies diverge."""
    a = RetryPolicy(seed=7)
    b = RetryPolicy(seed=7)
    assert a.nonce != b.nonce
    assert [a.delay(k) for k in range(5)] != [b.delay(k) for k in range(5)]


def test_put_batch_retries_transient_failure_then_succeeds():
    """A provider that blips for one RPC must not fail the write: the retry
    layer absorbs it (and counts it), the health machine sees both sides."""
    slept = []
    cluster = Cluster(
        n_data_providers=2, shared_cache_bytes=0,
        retry_policy=RetryPolicy(max_attempts=3, sleep=slept.append),
    )
    provider = cluster.provider_manager.get_provider(0)
    real_put = provider.put_pages
    blips = {"left": 1}

    def flaky_put(items):
        if blips["left"]:
            blips["left"] -= 1
            raise ProviderFailed("injected blip")
        return real_put(items)

    provider.put_pages = flaky_put
    sess = cluster.session(cache_bytes=0)
    handle = sess.create(8 * PAGE, PAGE)
    v = handle.write(np.full(4 * PAGE, 9, np.uint8), 0)
    assert slept, "backoff must have run"
    assert cluster.stats.retries >= 1
    np.testing.assert_array_equal(
        handle.read(0, 4 * PAGE, version=v).data, np.full(4 * PAGE, 9, np.uint8)
    )
    assert cluster.provider_manager.health_state(0) == "live"  # success cleared
    cluster.close()


def test_writev_replaces_dead_providers_batch_midflight():
    """Tentpole: a provider that dies AFTER placement does not abort the
    writev — its batch is re-put on healthy providers, the leaves are
    corrected, and the version publishes with full replication."""
    cluster = Cluster(
        n_data_providers=3, page_replication=2, shared_cache_bytes=0,
        retry_policy=RetryPolicy(max_attempts=2, sleep=lambda s: None),
        health=HealthConfig(dead_after=1),
    )
    sess = cluster.session(cache_bytes=0)
    handle = sess.create(8 * PAGE, PAGE)
    provider = cluster.provider_manager.get_provider(0)
    started, release = threading.Event(), threading.Event()
    real_put = provider.put_pages

    def dying_put(items):
        started.set()
        assert release.wait(10)
        return real_put(items)

    provider.put_pages = dying_put
    versions = []
    t = threading.Thread(
        target=lambda: versions.append(handle.write(np.full(6 * PAGE, 5, np.uint8), 0))
    )
    t.start()
    assert started.wait(10)
    cluster.provider_manager.fail_provider(0)  # dies mid-flight
    release.set()
    t.join(10)
    assert versions == [1], "write must complete despite the death"
    # the published version's leaves reference only live providers
    for key, node in cluster.metadata.iter_nodes(handle.blob_id):
        if node.is_leaf:
            pids = [pid for pid, _ in node.all_page_refs()]
            assert 0 not in pids
            assert len(set(pids)) == 2  # replication preserved
    # and the data is truly there (no cache: straight from the providers)
    np.testing.assert_array_equal(
        handle.read(0, 6 * PAGE, version=1).data, np.full(6 * PAGE, 5, np.uint8)
    )
    assert cluster.stats.retries >= 1
    cluster.close()


def test_degraded_read_falls_back_and_counts():
    """Reads of data with a dead replica complete through the survivors and
    are counted as degraded (the operator's signal that redundancy is low)."""
    cluster = Cluster(n_data_providers=3, page_replication=2,
                      shared_cache_bytes=0)
    sess = cluster.session(cache_bytes=0)
    handle = sess.create(8 * PAGE, PAGE)
    data = np.arange(8 * PAGE, dtype=np.uint8)
    v = handle.write(data, 0)
    cluster.provider_manager.fail_provider(0)
    out = handle.read(0, 8 * PAGE, version=v).data
    np.testing.assert_array_equal(out, data)
    assert cluster.stats.replica_fallbacks >= 1
    assert cluster.stats.degraded_reads >= 1
    assert cluster.provider_manager.health_state(0) in ("suspect", "dead")
    cluster.close()


# ------------------------------- repair ----------------------------------------


def test_repair_restores_replication_factor():
    """Re-replication: after a provider is declared dead, a repair pass
    copies its published pages from survivors onto healthy providers and
    rewrites the leaves — the replication factor is whole again."""
    cluster = Cluster(n_data_providers=4, page_replication=2,
                      shared_cache_bytes=0, health=HealthConfig(dead_after=1))
    pm = cluster.provider_manager
    pm.on_dead = None  # drive the pass by hand for determinism
    sess = cluster.session(cache_bytes=0)
    handle = sess.create(16 * PAGE, PAGE)
    data = np.random.default_rng(3).integers(0, 255, 16 * PAGE, dtype=np.uint8)
    v = handle.write(data, 0)
    pm.fail_provider(0)
    pm.note_failure(0)
    assert pm.dead_providers() == [0]
    repaired, _ = cluster.repair_service.run_once()
    assert repaired > 0
    assert cluster.stats.repaired_pages == repaired
    for key, node in cluster.metadata.iter_nodes(handle.blob_id):
        if node.is_leaf:
            refs = node.all_page_refs()
            pids = {pid for pid, _ in refs}
            assert 0 not in pids, "leaves must stop referencing the dead node"
            assert len(pids) == 2, "replication factor restored"
            for pid, page_key in refs:
                assert pm.get_provider(pid).has_page(page_key)
    np.testing.assert_array_equal(
        sess.open(handle.blob_id).read(0, 16 * PAGE, version=v).data, data
    )
    cluster.close()


def test_death_schedules_background_repair():
    """The on_dead hook queues repair on the aux pool automatically."""
    cluster = Cluster(n_data_providers=4, page_replication=2,
                      shared_cache_bytes=0, health=HealthConfig(dead_after=1))
    sess = cluster.session(cache_bytes=0)
    handle = sess.create(8 * PAGE, PAGE)
    handle.write(np.full(8 * PAGE, 3, np.uint8), 0)
    cluster.provider_manager.fail_provider(1)
    cluster.provider_manager.note_failure(1)  # -> dead -> schedule()
    deadline = threading.Event()
    for _ in range(200):  # aux-pool pass is asynchronous: poll briefly
        if all(
            1 not in {pid for pid, _ in node.all_page_refs()}
            for key, node in cluster.metadata.iter_nodes(handle.blob_id)
            if node.is_leaf
        ):
            break
        deadline.wait(0.02)
    assert cluster.repair_service.last_error is None
    assert all(
        1 not in {pid for pid, _ in node.all_page_refs()}
        for key, node in cluster.metadata.iter_nodes(handle.blob_id)
        if node.is_leaf
    )
    cluster.close()


def test_unrepairable_when_all_replicas_dead_is_skipped():
    cluster = Cluster(n_data_providers=2, page_replication=2,
                      shared_cache_bytes=0, health=HealthConfig(dead_after=1))
    pm = cluster.provider_manager
    pm.on_dead = None
    sess = cluster.session(cache_bytes=0)
    handle = sess.create(4 * PAGE, PAGE)
    handle.write(np.full(4 * PAGE, 1, np.uint8), 0)
    for pid in (0, 1):
        pm.fail_provider(pid)
        pm.note_failure(pid)
    repaired, _ = cluster.repair_service.run_once()
    assert repaired == 0  # nothing to copy FROM; no crash, no bogus rewrite
    cluster.close()


# --------------------------- writer recovery / scrub ---------------------------


def _make_hole(cluster, sess, handle):
    """Drive a writer into a publication hole: writer A blocks on its data
    put, writer B is assigned after it, then every provider dies so A's
    re-placement finds no target and A aborts. Returns B's version."""
    blob = handle.blob_id
    provider = cluster.provider_manager.get_provider(0)
    started, release = threading.Event(), threading.Event()
    real_put = provider.put_pages

    def blocked_put(items):
        started.set()
        assert release.wait(10)
        return real_put(items)

    provider.put_pages = blocked_put
    failures = []

    def writer_a():
        try:
            handle.write(np.full(PAGE, 1, np.uint8), 0)
        except ProviderFailed as err:
            failures.append(err)

    t = threading.Thread(target=writer_a)
    t.start()
    assert started.wait(10)
    for _ in range(500):
        if cluster.version_manager.assigned_versions(blob) >= 1:
            break
        threading.Event().wait(0.01)
    v2 = cluster.session(cache_bytes=0).open(blob).write(
        np.full(PAGE, 2, np.uint8), PAGE
    )
    for pid in (0, 1):
        cluster.provider_manager.fail_provider(pid)
    release.set()
    t.join(10)
    provider.put_pages = real_put
    assert failures, "A must abort once no healthy target remains"
    cluster.provider_manager.recover_provider(1)
    return v2


def test_hole_readers_redirect_around_dangling_links():
    """Writer recovery, read side: B published with border links woven
    against A's hole; readers resolve them to surviving versions instead of
    crashing on missing nodes."""
    cluster = Cluster(n_data_providers=2, shared_cache_bytes=0,
                      retry_policy=RetryPolicy(max_attempts=1))
    sess = cluster.session(cache_bytes=0)
    handle = sess.create(8 * PAGE, PAGE)
    v2 = _make_hole(cluster, sess, handle)
    vm = cluster.version_manager
    assert vm.latest_published(handle.blob_id) == v2
    assert vm.aborted_view(handle.blob_id) == frozenset([1])
    reader = cluster.session(cache_bytes=0).open(handle.blob_id)
    np.testing.assert_array_equal(
        reader.read(PAGE, PAGE, version=v2).data, np.full(PAGE, 2, np.uint8)
    )
    # the region A never published reads as zeros, not as A's lost bytes
    np.testing.assert_array_equal(
        reader.read(0, PAGE, version=v2).data, np.zeros(PAGE, np.uint8)
    )
    cluster.close()


def test_scrub_unlinks_dangling_links_and_reclaims_wreckage():
    """Writer recovery, scrub side: the metadata scrub rewrites inner links
    pointing into the hole and deletes the hole's stored nodes/pages —
    reads stay correct before AND after."""
    cluster = Cluster(n_data_providers=2, shared_cache_bytes=0,
                      retry_policy=RetryPolicy(max_attempts=1))
    sess = cluster.session(cache_bytes=0)
    handle = sess.create(8 * PAGE, PAGE)
    blob = handle.blob_id
    v2 = _make_hole(cluster, sess, handle)
    hole_nodes_before = [
        key for key, _ in cluster.metadata.iter_nodes(blob) if key.version == 1
    ]
    scrubbed = cluster.repair_service.scrub(blob)
    assert scrubbed >= len(hole_nodes_before)
    assert not any(
        key.version == 1 for key, _ in cluster.metadata.iter_nodes(blob)
    ), "hole wreckage gone"
    assert not any(
        node.left_version == 1 or node.right_version == 1
        for _, node in cluster.metadata.iter_nodes(blob)
        if not node.is_leaf
    ), "no stored link reaches the hole anymore"
    reader = cluster.session(cache_bytes=0).open(blob)
    np.testing.assert_array_equal(
        reader.read(PAGE, PAGE, version=v2).data, np.full(PAGE, 2, np.uint8)
    )
    cluster.close()


def test_abandon_journal_replay_reconstructs_state():
    """Satellite: recover() on a journal with interleaved assign / success /
    abandon entries rebuilds the same publish frontier, holes, and per-page
    version array as the live manager."""
    vm = VersionManager()
    blob = vm.alloc(16, PAGE)
    v1, _ = vm.assign_version(blob, 0, 4)
    v2, _ = vm.assign_version(blob, 2, 4)
    v3, _ = vm.assign_version(blob, 8, 4)
    vm.report_success(blob, v1)
    vm.abandon(blob, [v2])          # hole (v3 assigned after it)
    vm.report_success(blob, v3)
    v4, _ = vm.assign_version(blob, 0, 2)
    vm.abandon(blob, [v4])          # tail erase: number reused
    v4b, _ = vm.assign_version(blob, 12, 4)
    assert v4b == v4
    vm.report_success(blob, v4b)

    recovered, orphans = VersionManager.recover(list(vm.journal))
    assert recovered.latest_published(blob) == vm.latest_published(blob)
    assert recovered.aborted_view(blob) == vm.aborted_view(blob)
    assert recovered.assigned_versions(blob) == vm.assigned_versions(blob)
    np.testing.assert_array_equal(
        recovered._blobs[blob].page_versions, vm._blobs[blob].page_versions
    )
    assert orphans == {blob: []}


# ------------------------------ chaos harness ----------------------------------


def test_fault_schedule_generation_is_deterministic_and_bounded():
    a = FaultSchedule.generate(seed=11, n_providers=8, max_dead=2)
    b = FaultSchedule.generate(seed=11, n_providers=8, max_dead=2)
    assert a.events == b.events
    assert a.events != FaultSchedule.generate(seed=12, n_providers=8).events
    dead = set()
    for ev in a.events:
        if ev.action == KILL:
            dead.add(ev.provider_id)
            assert len(dead) <= 2
        elif ev.action == RECOVER:
            dead.discard(ev.provider_id)
    assert not dead, "generate(recover_all=True) must recover everyone"


def test_injector_drop_fails_exactly_one_rpc():
    cluster = Cluster(n_data_providers=1, shared_cache_bytes=0,
                      retry_policy=RetryPolicy(max_attempts=1))
    schedule = FaultSchedule([FaultEvent(1, DROP, 0)])
    injector = FaultInjector(cluster, schedule)
    injector.attach()
    sess = cluster.session(cache_bytes=0)
    handle = sess.create(4 * PAGE, PAGE)
    with pytest.raises(ProviderFailed, match="injected drop"):
        handle.write(np.full(PAGE, 1, np.uint8), 0)
    # the drop was one-shot: the very next write sails through
    v = handle.write(np.full(PAGE, 2, np.uint8), 0)
    injector.detach()
    np.testing.assert_array_equal(
        handle.read(0, PAGE, version=v).data, np.full(PAGE, 2, np.uint8)
    )
    cluster.close()


def test_injector_drop_is_absorbed_by_retry():
    cluster = Cluster(n_data_providers=1, shared_cache_bytes=0,
                      retry_policy=RetryPolicy(max_attempts=3,
                                               sleep=lambda s: None))
    injector = FaultInjector(cluster, FaultSchedule([FaultEvent(1, DROP, 0)]))
    injector.attach()
    sess = cluster.session(cache_bytes=0)
    handle = sess.create(4 * PAGE, PAGE)
    v = handle.write(np.full(PAGE, 7, np.uint8), 0)  # retry absorbs the drop
    injector.detach()
    assert v == 1
    assert cluster.stats.retries >= 1
    cluster.close()


# ----------------------- metadata plane: health + quorum -----------------------


def test_metadata_shard_health_machine():
    clock = FakeClock()
    dht = MetadataDHT(
        4, replication=2,
        health=HealthConfig(suspect_after=1, dead_after=3,
                            window_seconds=10.0, clock=clock),
    )
    assert dht.shard_health(0) == "live"
    dht.note_shard_failure(0)
    assert dht.shard_health(0) == "suspect"
    dht.note_shard_failure(0)
    dht.note_shard_failure(0)
    assert dht.shard_health(0) == "dead"
    assert dht.dead_shards() == [0]
    dht.note_shard_success(0)  # observed success is the recovery signal
    assert dht.shard_health(0) == "live"
    # failures age out of the window instead of accumulating forever
    dht.note_shard_failure(1)
    dht.note_shard_failure(1)
    clock.advance(11.0)
    assert dht.shard_health(1) == "live"


def test_metadata_shard_on_dead_fires_once_and_schedules_repair():
    dht = MetadataDHT(4, replication=2, health=HealthConfig(dead_after=2))
    deaths = []
    dht.on_dead = deaths.append
    for _ in range(5):
        dht.note_shard_failure(2)
    assert deaths == [2]


def test_metadata_write_commits_on_quorum_with_dead_replica():
    """With metadata_replication=2 the write quorum is 1: killing one shard
    loses at most one of each node's two consecutive homes, so writes keep
    committing and reads fall back to the survivor."""
    cluster = Cluster(
        n_data_providers=2, n_metadata_providers=4, metadata_replication=2,
        shared_cache_bytes=0,
        retry_policy=RetryPolicy(max_attempts=2, sleep=lambda s: None),
    )
    cluster.metadata.fail_shard(1)
    sess = cluster.session(cache_bytes=0)
    handle = sess.create(8 * PAGE, PAGE)
    data = np.arange(8 * PAGE, dtype=np.uint8)
    v = handle.write(data, 0)  # must commit: every node keeps >= 1 replica
    np.testing.assert_array_equal(handle.read(0, 8 * PAGE, version=v).data, data)
    cluster.close()


def test_metadata_write_aborts_cleanly_on_quorum_loss():
    """When a node cannot reach its write quorum on ANY replica the writev
    aborts through the normal abandon path — no partial publish, no hang,
    and the frontier stays where it was."""
    cluster = Cluster(
        n_data_providers=2, n_metadata_providers=4, metadata_replication=2,
        shared_cache_bytes=0,
        retry_policy=RetryPolicy(max_attempts=1, sleep=lambda s: None),
    )
    sess = cluster.session(cache_bytes=0)
    handle = sess.create(8 * PAGE, PAGE)
    v = handle.write(np.full(8 * PAGE, 3, np.uint8), 0)
    for sid in range(4):
        cluster.metadata.fail_shard(sid)
    with pytest.raises(ProviderFailed):
        handle.write(np.full(8 * PAGE, 4, np.uint8), 0)
    for sid in range(4):
        cluster.metadata.recover_shard(sid)
    assert handle.latest_published() == v  # frontier unmoved, hole withdrawn
    np.testing.assert_array_equal(
        handle.read(0, 8 * PAGE).data, np.full(8 * PAGE, 3, np.uint8)
    )
    cluster.close()


def test_metadata_transient_blip_absorbed_by_bounded_retry():
    """One flaky shard RPC is absorbed by the retry layer: counted in
    ``metadata_retries``, each backoff drawn from the bounded policy, and the
    shard's health returns to live on the retried success."""
    slept = []
    policy = RetryPolicy(max_attempts=3, sleep=slept.append)
    cluster = Cluster(
        n_data_providers=2, n_metadata_providers=4, metadata_replication=2,
        shared_cache_bytes=0, retry_policy=policy,
    )
    sess = cluster.session(cache_bytes=0)
    handle = sess.create(8 * PAGE, PAGE)
    data = np.arange(8 * PAGE, dtype=np.uint8)
    v = handle.write(data, 0)
    shard = cluster.metadata.shards[0]
    real_get_many = shard.get_many
    blips = {"left": 1}

    def flaky_get_many(keys):
        if blips["left"]:
            blips["left"] -= 1
            raise ProviderFailed("injected metadata blip")
        return real_get_many(keys)

    shard.get_many = flaky_get_many
    before = len(slept)
    np.testing.assert_array_equal(
        sess.open(handle.blob_id).read(0, 8 * PAGE, version=v).data, data
    )
    shard.get_many = real_get_many
    assert cluster.stats.metadata_retries >= 1
    new_sleeps = slept[before:]
    assert new_sleeps, "a retry must back off"
    bound = policy.max_delay_seconds * (1 + policy.jitter)
    assert all(0 <= s <= bound for s in new_sleeps)
    assert sum(new_sleeps) <= cluster.stats.metadata_retries * bound
    assert cluster.metadata.shard_health(0) == "live"  # success cleared it
    cluster.close()


def test_dead_metadata_shard_fails_fast_never_hangs_reads():
    """Acceptance: a dead shard replica never hangs a read. With the shard
    DECLARED dead the retry loop fails fast — the read completes through the
    surviving replica with ZERO backoff sleeps (asserted via the injected
    sleep, so the test itself never waits on wall clock)."""
    slept = []
    cluster = Cluster(
        n_data_providers=2, n_metadata_providers=4, metadata_replication=2,
        shared_cache_bytes=0,
        retry_policy=RetryPolicy(max_attempts=3, sleep=slept.append),
        health=HealthConfig(dead_after=2, clock=FakeClock()),
    )
    sess = cluster.session(cache_bytes=0)
    handle = sess.create(16 * PAGE, PAGE)
    data = np.arange(16 * PAGE, dtype=np.uint8)
    v = handle.write(data, 0)
    cluster.metadata.fail_shard(0)
    cluster.metadata.note_shard_failure(0)
    cluster.metadata.note_shard_failure(0)  # -> declared dead
    assert cluster.metadata.dead_shards() == [0]
    before = len(slept)
    np.testing.assert_array_equal(
        sess.open(handle.blob_id).read(0, 16 * PAGE, version=v).data, data
    )
    assert slept[before:] == [], "dead shards must not burn the retry budget"
    cluster.close()


def test_wedged_metadata_shard_bounded_by_rpc_timeout():
    """A shard that answers arbitrarily slowly (wedged, not crashed) costs
    one bounded timeout per attempt instead of hanging the read plane."""
    cluster = Cluster(
        n_data_providers=2, n_metadata_providers=4, metadata_replication=2,
        shared_cache_bytes=0,
        retry_policy=RetryPolicy(max_attempts=1, sleep=lambda s: None),
        metadata_timeout_seconds=0.05,
    )
    sess = cluster.session(cache_bytes=0)
    handle = sess.create(8 * PAGE, PAGE)
    data = np.arange(8 * PAGE, dtype=np.uint8)
    v = handle.write(data, 0)
    shard = cluster.metadata.shards[0]
    real_get_many = shard.get_many

    def wedged_get_many(keys):
        threading.Event().wait(0.3)  # far past the 50ms attempt budget
        return real_get_many(keys)

    shard.get_many = wedged_get_many
    np.testing.assert_array_equal(
        sess.open(handle.blob_id).read(0, 8 * PAGE, version=v).data, data
    )
    shard.get_many = real_get_many
    assert cluster.metadata.shard_health(0) in ("suspect", "dead")
    cluster.close()


def test_mid_writev_shard_kill_write_completes_and_repairs():
    """Tentpole mirror of the data-plane mid-flight death: a metadata shard
    that dies while its node batch is in flight does not abort the writev —
    the quorum rule publishes through the surviving replicas, and the repair
    pass rebuilds the dead replica's node set once the shard rejoins."""
    cluster = Cluster(
        n_data_providers=2, n_metadata_providers=4, metadata_replication=2,
        shared_cache_bytes=0,
        retry_policy=RetryPolicy(max_attempts=2, sleep=lambda s: None),
    )
    sess = cluster.session(cache_bytes=0)
    handle = sess.create(8 * PAGE, PAGE)
    shard = cluster.metadata.shards[2]
    started, release = threading.Event(), threading.Event()
    real_put_many = shard.put_many

    def dying_put_many(nodes):
        started.set()
        assert release.wait(10)
        return real_put_many(nodes)

    shard.put_many = dying_put_many
    data = np.arange(8 * PAGE, dtype=np.uint8)
    versions = []
    t = threading.Thread(target=lambda: versions.append(handle.write(data, 0)))
    t.start()
    if not started.wait(5):
        # no node of this write homes on shard 2: kill it anyway — the write
        # must still complete untouched
        pass
    cluster.metadata.fail_shard(2)  # dies mid-flight (put raises on release)
    release.set()
    t.join(10)
    shard.put_many = real_put_many
    assert versions == [1], "write must publish despite the mid-flight death"
    np.testing.assert_array_equal(handle.read(0, 8 * PAGE, version=1).data, data)
    # rejoin + repair: the dead replica's journal-covered node set is rebuilt
    cluster.metadata.recover_shard(2)
    cluster.repair_service.run_once()
    blob = handle.blob_id
    published, aborted = cluster.version_manager.repair_horizon(blob)
    for key, node in cluster.metadata.iter_nodes(blob):
        if key.version > published or key.version in aborted:
            continue
        for sid in cluster.metadata._replica_ids(key):
            assert cluster.metadata.shards[sid].get(key) is not None, (
                f"replica {sid} missing {key} after repair"
            )
    cluster.close()


# --------------------------- page integrity (checksums) ------------------------


def test_page_checksum_detects_corruption():
    # the checksum is a position-weighted word sum (it replaced zlib.crc32
    # on the fetch hot path): deterministic across buffer types, catches
    # single-byte flips anywhere, catches word swaps (pure sums would not),
    # and handles non-word-aligned tails
    rng = np.random.default_rng(7)
    page = rng.integers(0, 256, 4 * PAGE, dtype=np.uint8)
    base = page_checksum(page)
    assert base == page_checksum(page.copy())
    assert base == page_checksum(page.tobytes())
    for i in (0, 1, page.size // 2, page.size - 1):
        flipped = page.copy()
        flipped[i] ^= 0x01
        assert page_checksum(flipped) != base
    swapped = page.copy()
    words = swapped.view(np.uint64)
    words[0], words[3] = words[3].copy(), words[0].copy()
    assert page_checksum(swapped) != base
    tail = page[:37]
    assert page_checksum(tail) == page_checksum(tail.tobytes())
    assert page_checksum(tail) != page_checksum(page[:36])


def test_leaf_checksums_computed_at_freeze_time():
    cluster = Cluster(n_data_providers=2, shared_cache_bytes=0)
    sess = cluster.session(cache_bytes=0)
    handle = sess.create(4 * PAGE, PAGE)
    handle.write(np.arange(4 * PAGE, dtype=np.uint8), 0)
    pm = cluster.provider_manager
    leaves = 0
    for key, node in cluster.metadata.iter_nodes(handle.blob_id):
        if not node.is_leaf:
            continue
        leaves += 1
        assert node.checksum is not None
        pid, page_key = node.page
        assert page_checksum(pm.get_provider(pid).get_page(page_key)) == node.checksum
    assert leaves > 0
    cluster.close()


def _corrupt_stored_page(provider, page_key):
    bad = provider._pages[page_key].copy()
    bad[0] ^= 0xFF
    bad.flags.writeable = False
    provider._pages[page_key] = bad


def test_corrupt_page_read_falls_back_verifies_and_repairs():
    """Satellite: flip a byte in a stored page. The read must return the
    CORRECT bytes via a verified replica, count the checksum failure, and
    repair the corrupt copy in place."""
    cluster = Cluster(n_data_providers=3, page_replication=2,
                      shared_cache_bytes=0)
    sess = cluster.session(cache_bytes=0)
    handle = sess.create(8 * PAGE, PAGE)
    data = np.random.default_rng(5).integers(0, 255, 8 * PAGE, dtype=np.uint8)
    v = handle.write(data, 0)
    # corrupt the PRIMARY copy of the first leaf
    target = None
    for key, node in cluster.metadata.iter_nodes(handle.blob_id):
        if node.is_leaf and node.key.offset == 0:
            target = node
            break
    assert target is not None
    pid, page_key = target.page
    provider = cluster.provider_manager.get_provider(pid)
    _corrupt_stored_page(provider, page_key)
    out = sess.open(handle.blob_id).read(0, 8 * PAGE, version=v).data
    np.testing.assert_array_equal(out, data)  # corruption never surfaces
    assert cluster.stats.checksum_failures >= 1
    assert cluster.stats.repaired_pages >= 1
    # the bad copy was overwritten with verified bytes
    assert page_checksum(provider._pages[page_key]) == target.checksum
    cluster.close()


def test_repair_skips_corrupt_survivor_as_source():
    """A corrupt copy must never become the repair SOURCE: re-replication
    verifies each survivor against the leaf checksum and copies only
    verified bytes onto the replacement provider."""
    cluster = Cluster(n_data_providers=4, page_replication=3,
                      shared_cache_bytes=0, health=HealthConfig(dead_after=1))
    pm = cluster.provider_manager
    pm.on_dead = None  # drive the pass by hand
    sess = cluster.session(cache_bytes=0)
    handle = sess.create(4 * PAGE, PAGE)
    data = np.random.default_rng(7).integers(0, 255, 4 * PAGE, dtype=np.uint8)
    v = handle.write(data, 0)
    # pick one leaf: corrupt its primary's copy, kill one replica holder
    target = next(
        node for _, node in cluster.metadata.iter_nodes(handle.blob_id)
        if node.is_leaf and node.key.offset == 0
    )
    (bad_pid, bad_key), victims = target.page, target.replicas
    _corrupt_stored_page(pm.get_provider(bad_pid), bad_key)
    dead_pid = victims[0][0]
    pm.fail_provider(dead_pid)
    pm.note_failure(dead_pid)
    repaired, _ = cluster.repair_service.run_once()
    assert repaired > 0
    assert cluster.stats.checksum_failures >= 1  # the corrupt source was seen
    # every fresh copy of that leaf verifies against the freeze-time checksum
    for key, node in cluster.metadata.iter_nodes(handle.blob_id):
        if not node.is_leaf or node.key != target.key:
            continue
        for pid, page_key in node.all_page_refs():
            if pid == bad_pid:
                continue  # still holds its corrupt copy (read path repairs it)
            assert page_checksum(pm.get_provider(pid).get_page(page_key)) \
                == node.checksum
    np.testing.assert_array_equal(
        sess.open(handle.blob_id).read(0, 4 * PAGE, version=v).data, data
    )
    cluster.close()


# --------------------------- metadata chaos campaign ---------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_chaos_metadata_faults_zero_published_data_loss(seed):
    """Satellite chaos campaign: mixed writer/reader traffic while a seeded
    schedule kills/drops/delays METADATA shards (at most 1 of each node's 2
    replicas at a time) alongside light data-plane faults. Published
    versions must lose nothing, the frontier must stay monotone, and after
    drain + repair every journal-covered node is back on ALL its replica
    shards."""
    n_shards, meta_replication = 4, 2
    cluster = Cluster(
        n_data_providers=4, page_replication=2,
        n_metadata_providers=n_shards, metadata_replication=meta_replication,
        shared_cache_bytes=0,
        retry_policy=RetryPolicy(max_attempts=3, base_delay_seconds=0.001,
                                 max_delay_seconds=0.004),
        health=HealthConfig(dead_after=2, window_seconds=60.0),
    )
    writer_sessions = [cluster.session(cache_bytes=0) for _ in range(2)]
    blob = writer_sessions[0].create(64 * PAGE, PAGE).blob_id
    meta_faults = FaultSchedule.generate(
        seed=seed, n_providers=n_shards, n_events=8, max_dead=1,
        min_gap=3, max_gap=20, target=METADATA,
    )
    data_faults = FaultSchedule.generate(
        seed=seed + 100, n_providers=4, n_events=4, max_dead=1,
        min_gap=10, max_gap=40,
    )
    injector = FaultInjector(
        cluster, FaultSchedule(meta_faults.events + data_faults.events)
    )
    injector.attach()

    published = []
    published_lock = threading.Lock()
    errors = []
    n_rounds, regions = 8, 4

    def writer(wid, sess):
        handle = sess.open(blob)
        fill = 1
        for r in range(n_rounds):
            region = (wid * regions + r % regions) * 8
            value = (wid * 100 + fill) % 251 + 1
            fill += 1
            try:
                v = handle.write(
                    np.full(8 * PAGE, value, np.uint8), region * PAGE
                )
            except ProviderFailed:
                continue  # clean abort (quorum loss at that instant)
            with published_lock:
                published.append((v, region, 8, value))

    def reader():
        sess = cluster.session(cache_bytes=0)
        handle = sess.open(blob)
        last = 0
        for _ in range(30):
            v = handle.latest_published()
            assert v >= last, "publish frontier must be monotone"
            last = v
            if v:
                try:
                    handle.read(0, 64 * PAGE, version=v)
                except ProviderFailed as err:  # pragma: no cover
                    errors.append(err)
            threading.Event().wait(0.002)

    threads = [
        threading.Thread(target=writer, args=(i, s))
        for i, s in enumerate(writer_sessions)
    ] + [threading.Thread(target=reader) for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(60)
    assert not errors, f"reads failed under metadata chaos: {errors[:3]}"

    injector.drain()
    injector.detach()
    cluster.repair_service.run_once()

    checker = cluster.session(cache_bytes=0).open(blob)
    latest = checker.latest_published()
    for v, region, n, value in published:
        np.testing.assert_array_equal(
            checker.read(region * PAGE, n * PAGE, version=v).data,
            np.full(n * PAGE, value, np.uint8),
            err_msg=f"seed {seed}: version {v} lost data",
        )
    expected = np.zeros(64 * PAGE, np.uint8)
    for v, region, n, value in sorted(published):
        if v <= latest:
            expected[region * PAGE:(region + n) * PAGE] = value
    np.testing.assert_array_equal(
        checker.read(0, 64 * PAGE, version=latest).data, expected
    )
    # metadata replication restored: every journal-covered node on ALL homes
    published_frontier, aborted = cluster.version_manager.repair_horizon(blob)
    for key, node in cluster.metadata.iter_nodes(blob):
        if key.version > published_frontier or key.version in aborted:
            continue
        for sid in cluster.metadata._replica_ids(key):
            assert cluster.metadata.shards[sid].get(key) is not None, (
                f"seed {seed}: replica {sid} missing {key} after repair"
            )
    cluster.close()


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_chaos_mixed_traffic_zero_published_data_loss(seed):
    """THE acceptance chaos test: 8 providers, 3-way replication, live mixed
    traffic from multiple writer+reader sessions while a seeded schedule
    kills up to 2 providers at a time (and injects drops/delays). Published
    versions must lose nothing, reads must all complete, the frontier must
    be monotone, and repair must restore full replication after recovery."""
    n_providers, replication = 8, 3
    cluster = Cluster(
        n_data_providers=n_providers, page_replication=replication,
        shared_cache_bytes=0,
        retry_policy=RetryPolicy(max_attempts=3, base_delay_seconds=0.001,
                                 max_delay_seconds=0.004),
        health=HealthConfig(dead_after=2, window_seconds=60.0),
    )
    writer_sessions = [cluster.session(cache_bytes=0) for _ in range(2)]
    blob = writer_sessions[0].create(64 * PAGE, PAGE).blob_id
    schedule = FaultSchedule.generate(
        seed=seed, n_providers=n_providers, n_events=10, max_dead=2,
        min_gap=3, max_gap=25,
    )
    injector = FaultInjector(cluster, schedule)
    injector.attach()

    published = []  # (version, page_offset, n_pages, fill) — the oracle
    published_lock = threading.Lock()
    frontiers = []
    errors = []
    n_rounds, regions = 8, 4  # each writer owns `regions` disjoint regions

    def writer(wid, sess):
        handle = sess.open(blob)
        fill = 1
        for r in range(n_rounds):
            region = (wid * regions + r % regions) * 8  # 8-page regions
            value = (wid * 100 + fill) % 251 + 1
            fill += 1
            try:
                v = handle.write(
                    np.full(8 * PAGE, value, np.uint8), region * PAGE
                )
            except ProviderFailed:
                continue  # aborted cleanly (no healthy target at that instant)
            with published_lock:
                published.append((v, region, 8, value))

    def reader():
        sess = cluster.session(cache_bytes=0)
        handle = sess.open(blob)
        last = 0
        for _ in range(30):
            v = handle.latest_published()
            assert v >= last, "publish frontier must be monotone"
            frontiers.append(v)
            last = v
            if v:
                try:
                    handle.read(0, 64 * PAGE, version=v)
                except ProviderFailed as err:  # pragma: no cover - must not happen
                    errors.append(err)
            threading.Event().wait(0.002)

    threads = [
        threading.Thread(target=writer, args=(i, s))
        for i, s in enumerate(writer_sessions)
    ] + [threading.Thread(target=reader) for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(60)
    assert not errors, f"reads failed under chaos: {errors[:3]}"

    injector.drain()   # recover any provider still down
    injector.detach()
    repaired, scrubbed = cluster.repair_service.run_once()

    # -- zero data loss: every published write is byte-exact from providers
    checker = cluster.session(cache_bytes=0).open(blob)
    latest = checker.latest_published()
    for v, region, n, value in published:
        out = checker.read(region * PAGE, n * PAGE, version=v).data
        np.testing.assert_array_equal(
            out, np.full(n * PAGE, value, np.uint8),
            err_msg=f"seed {seed}: version {v} lost data",
        )
    # -- the full blob at the frontier matches the newest write per region
    expected = np.zeros(64 * PAGE, np.uint8)
    for v, region, n, value in sorted(published):
        if v <= latest:
            expected[region * PAGE:(region + n) * PAGE] = value
    np.testing.assert_array_equal(
        checker.read(0, 64 * PAGE, version=latest).data, expected
    )
    # -- replication factor restored on every published leaf
    pm = cluster.provider_manager
    aborted = cluster.version_manager.aborted_view(blob)
    for key, node in cluster.metadata.iter_nodes(blob):
        if not node.is_leaf or key.version > latest or key.version in aborted:
            continue
        refs = node.all_page_refs()
        pids = {pid for pid, _ in refs}
        # >= not ==: the replica balancer may have promoted hot pages to
        # EXTRA replicas under the reader traffic, which is fine
        assert len(pids) >= replication, (
            f"seed {seed}: leaf {key} under-replicated after repair: {refs}"
        )
        for pid, page_key in refs:
            provider = pm.get_provider(pid)
            assert not provider.failed
            assert provider.has_page(page_key)
    cluster.close()


# ------------------------------ node-plane chaos campaign ----------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_chaos_node_faults_zero_published_data_loss(seed):
    """THE federated acceptance chaos test: 4 nodes x 16 sessions of mixed
    traffic over one shared substrate while a seeded node-plane schedule
    kills / partitions / wedges whole nodes and a concurrent GC thread runs
    federated epoch/lease passes. Invariants (interleaving-independent):
    zero published-data loss for versions GC was told to keep, a monotone
    publish frontier on every node, and the lease invariant — after the
    final pass no node's cache tier holds a collected version."""
    n_nodes, writers_per_node, readers_per_node = 4, 2, 2
    fed = Federation(
        n_nodes=n_nodes,
        n_data_providers=4, page_replication=2,
        n_metadata_providers=4, metadata_replication=2,
        lease_seconds=0.05,
        retry_policy=RetryPolicy(max_attempts=2, base_delay_seconds=0.001,
                                 max_delay_seconds=0.004),
        health=HealthConfig(dead_after=2, window_seconds=60.0),
    )
    sessions = [
        [fed.nodes[n].session() for _ in range(writers_per_node + readers_per_node)]
        for n in range(n_nodes)
    ]
    assert sum(len(s) for s in sessions) == 16
    blob = sessions[0][0].create(64 * PAGE, PAGE).blob_id

    schedule = FaultSchedule.generate(
        seed=seed, n_providers=n_nodes, n_events=10, max_dead=2,
        min_gap=5, max_gap=30, target=NODE,
    )
    injector = FaultInjector(fed, schedule)
    injector.attach()

    published = []  # (version, region, value) oracle, appended post-ack only
    published_lock = threading.Lock()
    errors = []
    gc_floors = []  # keep-version of each mid-campaign GC pass
    stop_gc = threading.Event()
    n_rounds = 6

    def writer(node_i, slot, sess):
        wid = node_i * writers_per_node + slot
        handle = sess.open(blob)
        region = wid * 8  # each writer owns its 8-page region
        for r in range(n_rounds):
            value = (wid * 31 + r) % 251 + 1
            try:
                v = handle.write(np.full(8 * PAGE, value, np.uint8),
                                 region * PAGE)
            except (ProviderFailed, ValueError):
                continue  # node down / writer recovered: never acked
            with published_lock:
                published.append((v, region, value))

    def reader(node_i, sess):
        handle = sess.open(blob)
        last = 0
        for _ in range(20):
            v = handle.latest_published()
            assert v >= last, "publish frontier must be monotone"
            last = v
            try:
                snap = handle.at(None)  # federated pin: GC must honor it
            except (ProviderFailed, ValueError):
                threading.Event().wait(0.002)
                continue  # node down or partitioned: pin safely refused
            try:
                data = snap.read(0, 64 * PAGE)
                for w in range(n_nodes * writers_per_node):
                    region = data[w * 8 * PAGE:(w + 1) * 8 * PAGE]
                    vals = set(np.unique(region).tolist())
                    if len(vals) > 1:
                        errors.append(
                            f"torn region {w} at v{snap.version}: {sorted(vals)}"
                        )
            except ProviderFailed:
                pass  # node died mid-read: acceptable, data loss is not
            finally:
                snap.release()
            threading.Event().wait(0.002)

    def gc_loop():
        while not stop_gc.wait(0.02):
            latest = fed.version_manager.latest_published(blob)
            if latest:
                fed.gc(blob, keep_versions=[latest])
                gc_floors.append(latest)

    threads = (
        [threading.Thread(target=writer, args=(n, s, sessions[n][s]))
         for n in range(n_nodes) for s in range(writers_per_node)]
        + [threading.Thread(target=reader, args=(n, sessions[n][writers_per_node + s]))
           for n in range(n_nodes) for s in range(readers_per_node)]
        + [threading.Thread(target=gc_loop)]
    )
    for t in threads:
        t.start()
    for t in threads[:-1]:
        t.join(120)
    stop_gc.set()
    threads[-1].join(120)
    assert not errors, f"seed {seed}: stale/torn reads: {errors[:3]}"

    injector.drain()  # recover_all rejoins every downed node
    injector.detach()
    fed.repair_service.run_once()
    assert all(fed.node_mode(i) == "up" for i in range(n_nodes))

    # -- zero published-data loss: every acked write GC never collected
    floor = max(gc_floors, default=0)
    checker = fed.nodes[1].session(cache_bytes=0).open(blob)
    latest = checker.latest_published()
    for v, region, value in published:
        if v < floor and v != latest:
            continue  # collected by an explicit keep-latest GC pass
        np.testing.assert_array_equal(
            checker.read(region * PAGE, 8 * PAGE, version=v).data,
            np.full(8 * PAGE, value, np.uint8),
            err_msg=f"seed {seed}: version {v} lost data",
        )
    # -- the frontier composite matches the newest surviving write per region
    expected = np.zeros(64 * PAGE, np.uint8)
    for v, region, value in sorted(published):
        if v <= latest:
            expected[region * PAGE:(region + 8) * PAGE] = value
    np.testing.assert_array_equal(
        checker.read(0, 64 * PAGE, version=latest).data, expected
    )

    # -- lease invariant: after a final federated pass, no node's shared
    #    tier holds a collected version (every live node acked the epoch)
    fed.gc(blob, keep_versions=[latest])
    for i in range(n_nodes):
        for cached_v in fed.nodes[i].shared_cache.cached_versions(blob):
            assert cached_v == 0 or cached_v >= latest, (
                f"seed {seed}: node {i} caches collected v{cached_v}"
            )
    assert fed.coordinator.epoch() > 1
    fed.close()
