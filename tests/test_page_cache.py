"""Versioned page cache + batched readv/writev data plane tests.

The session-level tests run clusters WITHOUT the shared tier so the private
cache behaves as the standalone client cache of the original design;
cross-session shared-tier behavior is covered by tests/test_sessions.py.
"""

import threading

import numpy as np
import pytest

from repro.core import Cluster, PageCache, ProviderFailed, TrafficStats
from repro.core.provider import DataProvider

PAGE = 64


def make_session(**kw):
    session_kw = {
        k: kw.pop(k)
        for k in ("cache_bytes", "replica_spread", "sync_write", "max_inflight_writes")
        if k in kw
    }
    kw.setdefault("n_data_providers", 4)
    kw.setdefault("n_metadata_providers", 4)
    kw.setdefault("shared_cache_bytes", 0)
    return Cluster(**kw).session(**session_kw)


def page(fill, nbytes=PAGE):
    return np.full(nbytes, fill, np.uint8)


# ------------------------------- PageCache unit ------------------------------


def test_lru_eviction_respects_byte_budget():
    cache = PageCache(capacity_bytes=4 * PAGE)
    for i in range(4):
        cache.put((0, 1, i), page(i))
    assert len(cache) == 4 and cache.used_bytes() == 4 * PAGE
    cache.get((0, 1, 0))  # touch page 0 → page 1 is now LRU
    cache.put((0, 1, 4), page(4))
    assert cache.used_bytes() <= 4 * PAGE
    assert (0, 1, 1) not in cache  # the LRU entry was evicted
    assert (0, 1, 0) in cache and (0, 1, 4) in cache
    assert cache.evictions == 1


def test_oversized_page_never_cached():
    cache = PageCache(capacity_bytes=PAGE)
    cache.put((0, 1, 0), page(1))
    cache.put((0, 1, 1), page(2, nbytes=2 * PAGE))  # exceeds whole budget
    assert (0, 1, 1) not in cache
    assert (0, 1, 0) in cache  # and it did not wipe the existing entry


def test_cached_pages_are_immutable():
    cache = PageCache(capacity_bytes=4 * PAGE)
    cache.put((0, 1, 0), page(7))
    got = cache.get((0, 1, 0))
    with pytest.raises(ValueError):
        got[0] = 99


def test_plan_deduplicates_keys_within_one_call():
    """A duplicate key in one plan() must not appear in waits for the flight
    that same call created (it would self-deadlock a waits-first caller)."""
    cache = PageCache(capacity_bytes=4 * PAGE)
    plan = cache.plan([(0, 1, 0), (0, 1, 0), (0, 1, 0)])
    assert plan.owned == [(0, 1, 0)]
    assert not plan.waits and not plan.hits
    cache.fulfill((0, 1, 0), page(1))
    plan2 = cache.plan([(0, 1, 0), (0, 1, 0)])
    assert list(plan2.hits) == [(0, 1, 0)] and not plan2.owned


def test_stats_count_hits_and_misses():
    stats = TrafficStats()
    cache = PageCache(capacity_bytes=4 * PAGE, stats=stats)
    plan = cache.plan([(0, 1, 0), (0, 1, 1)])
    assert stats.cache_misses == 2 and stats.cache_hits == 0
    for key in plan.owned:
        cache.fulfill(key, page(1))
    cache.plan([(0, 1, 0), (0, 1, 1), (0, 1, 2)])
    assert stats.cache_hits == 2 and stats.cache_misses == 3
    # record=False leaves the accounting to the caller (tiered sessions)
    cache.plan([(0, 1, 0)], record=False)
    assert stats.cache_hits == 2 and stats.cache_misses == 3


def test_get_many_bulk_hits_without_single_flight():
    cache = PageCache(capacity_bytes=4 * PAGE)
    cache.put((0, 1, 0), page(1))
    cache.put((0, 1, 2), page(3))
    got = cache.get_many([(0, 1, 0), (0, 1, 1), (0, 1, 2)])
    assert set(got) == {(0, 1, 0), (0, 1, 2)}
    # misses must NOT open in-flight entries (no leader obligation)
    plan = cache.plan([(0, 1, 1)])
    assert plan.owned == [(0, 1, 1)]
    cache.fulfill((0, 1, 1), page(2))


# --------------------------- unpublished versions ----------------------------


def test_unpublished_versions_never_cached():
    sess = make_session()
    handle = sess.create(8 * PAGE, PAGE)
    handle.write(page(1, 8 * PAGE), 0)  # v1 published
    # simulate an in-flight writer: v2 assigned but never reported
    sess.cluster.version_manager.assign_version(handle.blob_id, 0, 1)
    with pytest.raises(ValueError, match="not yet published"):
        handle.read(0, PAGE, version=2)
    handle.read(0, 8 * PAGE)  # populates the cache with v1 pages
    assert sess.cache is not None
    assert sess.cache.cached_versions(handle.blob_id) == [1]
    sess.cluster.close()


def test_gc_purges_cache_of_dropped_versions():
    sess = make_session()
    handle = sess.create(8 * PAGE, PAGE)
    handle.write(page(1, 8 * PAGE), 0)  # v1
    handle.write(page(2, PAGE), 0)  # v2
    handle.read(0, 8 * PAGE, version=1)
    handle.read(0, 8 * PAGE, version=2)
    assert sess.cache.cached_versions(handle.blob_id) == [1, 2]
    sess.cluster.gc(handle.blob_id, keep_versions=[2])
    assert sess.cache.cached_versions(handle.blob_id) == [2]
    sess.cluster.close()


# ------------------------------- single-flight -------------------------------


def test_concurrent_cold_readers_one_fetch_per_page():
    sess = make_session(max_workers=32)
    handle = sess.create(16 * PAGE, PAGE)
    payload = np.arange(16 * PAGE, dtype=np.uint8) % 251
    handle.write(payload, 0)
    # drop the write-through entries: this test measures COLD readers
    sess.cache.clear()

    # count every page key fetched from any provider, and slow fetches down
    # so the reader threads genuinely overlap
    fetched_keys = []
    count_lock = threading.Lock()
    real_get_pages = DataProvider.get_pages
    slow = threading.Event()

    def counting_get_pages(self, page_keys):
        with count_lock:
            fetched_keys.extend(page_keys)
        slow.wait(0.05)
        return real_get_pages(self, page_keys)

    n_readers = 8
    barrier = threading.Barrier(n_readers)
    results = [None] * n_readers
    errors = []

    def reader(i):
        try:
            barrier.wait()
            results[i] = handle.read(0, 16 * PAGE, version=1).data
        except Exception as e:  # pragma: no cover
            errors.append(e)

    DataProvider.get_pages = counting_get_pages
    try:
        threads = [threading.Thread(target=reader, args=(i,)) for i in range(n_readers)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    finally:
        DataProvider.get_pages = real_get_pages

    assert not errors
    for r in results:
        np.testing.assert_array_equal(r, payload)
    # single-flight: every page fetched exactly once despite 8 cold readers
    assert len(fetched_keys) == 16
    assert len(set(fetched_keys)) == 16
    sess.cluster.close()


# --------------------------- readv / writev plane ----------------------------


def test_readv_equals_looped_read():
    sess = make_session(cache_bytes=0)
    handle = sess.create(32 * PAGE, PAGE)
    handle.write(np.arange(32 * PAGE, dtype=np.uint8) % 251, 0)
    segs = [(0, 3 * PAGE), (PAGE + 5, 2 * PAGE), (17, 30), (30 * PAGE, 5 * PAGE)]
    outs = handle.readv(segs)
    for (off, sz), got in zip(segs, outs):
        np.testing.assert_array_equal(got, handle.read(off, sz).data)
    sess.cluster.close()


def test_readv_fewer_rpc_rounds_than_looped_reads():
    """Acceptance: N overlapping segments cost strictly fewer provider RPC
    rounds via readv than via N separate read calls. The streaming read
    plane launches one aggregated get_pages round per provider per *emitted
    leaf batch* (a shard's slice of the final traversal level), so its bound
    is shards x providers; the phased ``sync_read`` baseline keeps the
    original one-round-per-provider aggregation."""
    sess = make_session(cache_bytes=0)
    handle = sess.create(64 * PAGE, PAGE)
    handle.write(np.arange(64 * PAGE, dtype=np.uint8) % 251, 0)
    segs = [(i * PAGE, 4 * PAGE) for i in range(0, 32, 2)]  # overlapping windows

    stats = sess.cluster.stats
    stats.reset()
    for off, sz in segs:
        handle.read(off, sz)
    looped_rounds = stats.data_rounds

    stats.reset()
    handle.readv(segs)
    readv_rounds = stats.data_rounds

    assert readv_rounds < looped_rounds
    # at most one aggregated round per (leaf-batch, provider) pair
    assert readv_rounds <= 4 * 4

    # the phased plane still aggregates to ONE round per data provider
    phased = sess.cluster.session(cache_bytes=0, sync_read=True)
    stats.reset()
    phased.open(handle.blob_id).readv(segs)
    assert stats.data_rounds <= 4
    sess.cluster.close()


def test_writev_equals_looped_write():
    a, b = make_session(cache_bytes=0), make_session(cache_bytes=0)
    ha, hb = a.create(16 * PAGE, PAGE), b.create(16 * PAGE, PAGE)
    patches = [(0, page(1, 2 * PAGE)), (4 * PAGE, page(2, PAGE)), (8 * PAGE, page(3, 4 * PAGE))]
    versions = ha.writev(patches)
    assert versions == [1, 2, 3]
    for off, buf in patches:
        hb.write(buf, off)
    for v in (1, 2, 3):
        np.testing.assert_array_equal(
            ha.read(0, 16 * PAGE, version=v).data,
            hb.read(0, 16 * PAGE, version=v).data,
        )
    a.cluster.close()
    b.cluster.close()


def test_writev_batches_provider_and_metadata_rounds():
    sess = make_session(cache_bytes=0)
    handle = sess.create(16 * PAGE, PAGE)
    patches = [(i * PAGE, page(i + 1)) for i in range(8)]

    stats = sess.cluster.stats
    stats.reset()
    handle.writev(patches)
    batched_data = stats.data_rounds
    batched_meta = stats.metadata_rounds
    # one aggregated put_pages per data provider, one node batch per shard
    assert batched_data <= 4
    assert batched_meta <= 4

    stats.reset()
    for off, buf in [(i * PAGE + 8 * PAGE, page(i)) for i in range(8)]:
        handle.write(buf, off)
    assert stats.data_rounds >= batched_data
    assert stats.metadata_rounds > batched_meta
    sess.cluster.close()


def test_readv_writev_under_concurrent_writers():
    """Vectored ops stay equivalent to looped ops while writers churn: a
    pinned published version read via readv matches page-by-page reads."""
    sess = make_session(max_workers=16)
    handle = sess.create(32 * PAGE, PAGE)
    base = np.arange(32 * PAGE, dtype=np.uint8) % 251
    handle.write(base, 0)
    stop = threading.Event()
    errors = []

    def writer(seed):
        mine = sess.cluster.session().open(handle.blob_id)
        rng = np.random.default_rng(seed)
        while not stop.is_set():
            off = int(rng.integers(0, 16)) * PAGE
            mine.writev([(off, page(int(rng.integers(1, 255))))])

    writers = [threading.Thread(target=writer, args=(s,)) for s in range(3)]
    for t in writers:
        t.start()
    try:
        for _ in range(25):
            v = handle.latest_published()
            segs = [(0, 8 * PAGE), (4 * PAGE, 8 * PAGE), (20 * PAGE, 12 * PAGE)]
            outs = handle.readv(segs, version=v)
            for (off, sz), got in zip(segs, outs):
                want = handle.read(off, sz, version=v).data
                np.testing.assert_array_equal(got, want)
    except Exception as e:  # pragma: no cover
        errors.append(e)
    finally:
        stop.set()
        for t in writers:
            t.join()
    assert not errors
    sess.cluster.close()


def test_zero_pages_cached_at_nominal_charge():
    """Implicit zero pages share one buffer, so they are cached at a nominal
    budget charge: repeat sparse reads skip the metadata walk entirely, yet
    zero entries cannot evict genuinely expensive provider-fetched pages."""
    from repro.core.page_cache import ZERO_PAGE_CHARGE

    sess = make_session()
    handle = sess.create(64 * PAGE, PAGE)
    handle.write(page(1), 0)  # only page 0 materialized
    got = handle.read(0, 64 * PAGE).data
    assert (got[:PAGE] == 1).all() and not got[PAGE:].any()
    assert len(sess.cache) == 64
    assert sess.cache.used_bytes() <= PAGE + 63 * ZERO_PAGE_CHARGE
    stats = sess.cluster.stats
    stats.reset()
    again = handle.read(0, 64 * PAGE).data  # fully cache-served
    np.testing.assert_array_equal(again, got)
    assert stats.metadata_rounds == 0 and stats.data_rounds == 0
    sess.cluster.close()


def test_metadata_outage_surfaces_as_provider_failed():
    """A full metadata outage must raise ProviderFailed (shard down), not
    KeyError (node lost) — same contract as the single-node get path."""
    sess = make_session(n_metadata_providers=2, cache_bytes=0)
    handle = sess.create(8 * PAGE, PAGE)
    handle.write(page(1, 8 * PAGE), 0)
    sess.cluster.metadata.fail_shard(0)
    sess.cluster.metadata.fail_shard(1)
    with pytest.raises(ProviderFailed):
        handle.readv([(0, 8 * PAGE)])
    sess.cluster.close()


# ------------------------------ read clamping --------------------------------


def test_read_clamped_at_blob_end_and_oob_rejected():
    """Regression: a read overlapping the blob's end must clamp (not traverse
    out-of-bounds tree ranges); a fully out-of-range read must raise."""
    sess = make_session()
    handle = sess.create(8 * PAGE, PAGE)
    payload = np.arange(8 * PAGE, dtype=np.uint8)
    handle.write(payload, 0)
    got = handle.read(6 * PAGE, 10 * PAGE).data  # overlaps the end
    assert got.size == 2 * PAGE
    np.testing.assert_array_equal(got, payload[6 * PAGE :])
    with pytest.raises(ValueError, match="out of range"):
        handle.read(8 * PAGE, PAGE)
    with pytest.raises(ValueError, match="negative"):
        handle.read(-1, PAGE)
    sess.cluster.close()
