"""End-to-end training integration: loss decreases, checkpoint/restart is
bit-consistent, data order is deterministic, failure injection recovers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Cluster
from repro.data.pipeline import PipelineConfig, TokenPipeline, write_token_corpus
from repro.launch.train import train


def make_session(**kw):
    kw.setdefault("n_data_providers", 4)
    kw.setdefault("n_metadata_providers", 4)
    kw.setdefault("shared_cache_bytes", 0)
    return Cluster(**kw).session()


def test_loss_decreases_small_lm():
    out = train("llama3_2-1b", smoke=True, steps=30, batch=8, seq=64,
                checkpoint_every=100, lr=1e-2)
    losses = out["losses"]
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5, losses


def test_checkpoint_restart_resumes_identically():
    """Train 20 steps; separately train 10, 'crash', restore, train 10 more —
    identical final loss (deterministic data order + exact state restore)."""
    a = train("llama3_2-1b", smoke=True, steps=20, batch=4, seq=64,
              checkpoint_every=10, seed=3)

    session = make_session()
    with pytest.raises(RuntimeError, match="injected failure"):
        train("llama3_2-1b", smoke=True, steps=20, batch=4, seq=64,
              checkpoint_every=10, seed=3, session=session, fail_at_step=14)
    # restart on the same session: restores step-10 checkpoint, resumes data at 10
    b = train("llama3_2-1b", smoke=True, steps=20, batch=4, seq=64,
              checkpoint_every=10, seed=3, session=session, restore=True)

    np.testing.assert_allclose(a["losses"][-1], b["losses"][-1], rtol=1e-4)


def test_moe_training_runs_and_balances():
    out = train("mixtral-8x7b", smoke=True, steps=10, batch=4, seq=64,
                checkpoint_every=100)
    assert np.isfinite(out["losses"]).all()


def test_ssm_training_runs():
    out = train("mamba2-370m", smoke=True, steps=10, batch=4, seq=64,
                checkpoint_every=100)
    assert np.isfinite(out["losses"]).all()


def test_pipeline_determinism_and_disjoint_ranks():
    session = make_session()
    rng = np.random.default_rng(0)
    n_tokens = 1 << 16
    corpus = rng.integers(0, 1000, n_tokens, dtype=np.int32)
    handle = write_token_corpus(session, corpus)

    def make(rank, n_ranks=4):
        return TokenPipeline(
            handle, n_tokens,
            PipelineConfig(batch_per_rank=2, seq_len=32, n_ranks=n_ranks, rank=rank),
        )

    p0a, p0b, p1 = make(0), make(0), make(1)
    b0a = p0a.batch_at(5)
    b0b = p0b.batch_at(5)
    np.testing.assert_array_equal(b0a["tokens"], b0b["tokens"])  # determinism
    b1 = p1.batch_at(5)
    assert not np.array_equal(b0a["tokens"], b1["tokens"])  # rank disjointness
    # labels are inputs shifted by one
    np.testing.assert_array_equal(b0a["tokens"][:, 1:], b0a["labels"][:, :-1])


def test_pipeline_straggler_redundant_fetch():
    """A provider failing mid-read must not stall the pipeline (replica
    fallback inside BlobHandle.read + redundant fetch)."""
    session = make_session(page_replication=2)
    rng = np.random.default_rng(0)
    n_tokens = 1 << 14
    handle = write_token_corpus(session, rng.integers(0, 100, n_tokens, dtype=np.int32))
    pipe = TokenPipeline(
        handle, n_tokens,
        PipelineConfig(batch_per_rank=2, seq_len=32, n_ranks=1, rank=0,
                       fetch_timeout_s=0.5),
    )
    session.cluster.provider_manager.fail_provider(0)  # node loss
    batch = pipe.batch_at(0)
    assert batch["tokens"].shape == (2, 32)