"""Per-architecture smoke tests: reduced config, one forward/train step and a
prefill→decode handoff on CPU; asserts output shapes and no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch.specs import concrete_batch
from repro.models.lm import build_model

BATCH, SEQ = 2, 32


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


def _setup(arch_id):
    cfg = get_config(arch_id).smoke()
    model = build_model(cfg)
    params, axes = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_train_step_smoke(arch_id):
    cfg, model, params = _setup(arch_id)
    batch = concrete_batch(cfg, BATCH, SEQ, "train")
    loss, metrics = jax.jit(lambda p, b: model.train_loss(p, b, None))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch_id}: non-finite loss {loss}"
    grads = jax.jit(
        jax.grad(lambda p, b: model.train_loss(p, b, None)[0])
    )(params, batch)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32)**2) for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)), f"{arch_id}: non-finite grad norm"
    assert float(gnorm) > 0, f"{arch_id}: zero gradients"


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_prefill_decode_smoke(arch_id):
    cfg, model, params = _setup(arch_id)
    batch = concrete_batch(cfg, BATCH, SEQ, "prefill")
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, None))(params, batch)
    assert logits.shape == (BATCH, cfg.padded_vocab)
    tokens = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)
    for _ in range(3):
        logits, cache = jax.jit(lambda p, c, t: model.decode_step(p, c, t, None))(
            params, cache, tokens
        )
        assert logits.shape == (BATCH, cfg.padded_vocab)
        assert bool(jnp.isfinite(logits).all()), f"{arch_id}: non-finite decode logits"
        tokens = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)


@pytest.mark.parametrize("arch_id", ["yi-9b", "mixtral-8x7b", "mamba2-370m"])
def test_decode_matches_prefill_continuation(arch_id):
    """Teacher-forced decode after prefill must match a longer prefill's
    logits (cache correctness end-to-end)."""
    cfg, model, params = _setup(arch_id)
    full = concrete_batch(cfg, BATCH, SEQ, "prefill", seed=1)
    if cfg.input_kind != "tokens":
        pytest.skip("token-input families only")
    tokens_full = full["tokens"]
    cut = SEQ - 8  # must stay page-aligned (page_tokens=8 in smoke configs)

    logits_full, _ = jax.jit(lambda p, b: model.prefill(p, b, None))(params, {"tokens": tokens_full})

    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, None))(
        params, {"tokens": tokens_full[:, :cut]}
    )
    for i in range(cut, SEQ):
        logits, cache = jax.jit(lambda p, c, t: model.decode_step(p, c, t, None))(
            params, cache, tokens_full[:, i]
        )
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(logits_full), rtol=2e-2, atol=2e-2
    )
