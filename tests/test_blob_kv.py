"""Blob-backed KV serving plane (docs/SERVING.md): slot bookkeeping on the
pool blob, the cluster-wide content-addressed prefix directory (frontier
gating, snapshot pinning, refcounted eviction), publish/gather round-trips,
pool-pressure backpressure, balancer coupling under hot-prefix skew, GC
safety, and the serving benchmark's CI regression gate."""

import numpy as np
import pytest

from repro.core import BalancerConfig, Cluster
from repro.serving.blob_kv import (
    BlobKVClient,
    BlobKVStore,
    kv_page_nbytes,
    pack_kv_page,
    unpack_kv_page,
)
from repro.storage.kvcache import chain_hash

T = 4  # page_tokens for every store in this file


def make_cluster(**kw):
    kw.setdefault("n_data_providers", 4)
    kw.setdefault("n_metadata_providers", 2)
    kw.setdefault("shared_cache_bytes", 0)
    return Cluster(**kw)


def make_store(cluster, n_pages=8, page_bytes=64):
    return BlobKVStore(cluster, n_pages, page_bytes=page_bytes, page_tokens=T)


def page_payload(store, fill):
    return np.full(store.page_size, fill % 251, np.uint8)


def publish_prompt(client, prompt, fill=1):
    """admit + publish every fresh FULL prompt page; returns the live seq."""
    seq, _, _ = client.admit(prompt)
    payloads = {
        p: page_payload(client.store, fill + p)
        for p in range(seq.n_shared_pages, len(prompt) // T)
    }
    client.publish_prompt(seq, payloads)
    return seq


# ------------------------------ page packing ------------------------------
def test_kv_page_pack_unpack_roundtrip():
    shape = (2, T, 3, 5)  # (L, T, K, hd)
    nbytes = kv_page_nbytes(2, T, 3, 5, np.float32)
    page_size = 1 << (nbytes - 1).bit_length()
    rng = np.random.default_rng(0)
    k = rng.normal(size=shape).astype(np.float32)
    v = rng.normal(size=shape).astype(np.float32)
    buf = pack_kv_page(k, v, page_size)
    assert buf.shape == (page_size,) and buf.dtype == np.uint8
    k2, v2 = unpack_kv_page(buf, shape, np.float32)
    np.testing.assert_array_equal(k, k2)
    np.testing.assert_array_equal(v, v2)
    with pytest.raises(ValueError):
        pack_kv_page(k, v, nbytes // 2)  # payload must fit the blob page


# ------------------------- cross-client prefix sharing ---------------------
def test_prefix_shared_across_clients_zero_duplicate_storage():
    cluster = make_cluster()
    store = make_store(cluster, n_pages=16)
    a, b = BlobKVClient(store), BlobKVClient(store)
    prompt = [1, 2, 3, 4, 5, 6, 7, 8]  # two full pages
    seq_a = publish_prompt(a, prompt, fill=10)
    used = store.used_slots
    seq_b, shared, fetches = b.admit(prompt)
    assert shared == len(prompt)  # the whole prompt resolved in the directory
    assert [i for i, _ in fetches] == [0, 1]
    assert seq_b.slots[:2] == seq_a.slots[:2]  # same blob pages
    assert store.used_slots == used  # zero duplicate storage
    # the fetched bytes are exactly what A published
    bufs = b.fetch_pages([addr for _, addr in fetches])
    np.testing.assert_array_equal(bufs[0], page_payload(store, 10))
    np.testing.assert_array_equal(bufs[1], page_payload(store, 11))
    a.finish(seq_a)
    # A finishing never disturbs B: the pages are published blob versions
    np.testing.assert_array_equal(
        b.fetch_pages([seq_b.page_addr[0]])[0], page_payload(store, 10)
    )
    b.finish(seq_b)
    cluster.close()


def test_gather_compiles_one_read_per_version_group():
    cluster = make_cluster()
    store = make_store(cluster, n_pages=16)
    client = BlobKVClient(store)
    # 3 full pages published as ONE writev -> contiguous slots -> one version
    seq = publish_prompt(client, list(range(3 * T)), fill=1)
    assert len({a.version for a in seq.page_addr}) == 1
    reads_before = client.stats["gather_reads"]
    out = client.gather(seq)
    assert [i for i, _ in out] == [0, 1, 2]
    assert client.stats["gather_reads"] == reads_before + 1  # one readv plan
    client.finish(seq)
    cluster.close()


# --------------------------- the frontier invariant -------------------------
def test_unpublished_version_impossible_to_register_or_read():
    """Acceptance criterion: a cross-session read of an unpublished KV page
    is impossible by construction — registration pins through the publish
    frontier and ``read_pages`` validates against it."""
    cluster = make_cluster()
    store = make_store(cluster)
    client = BlobKVClient(store)
    seq = publish_prompt(client, [1, 2, 3, 4], fill=3)
    latest = cluster.version_manager.latest_published(store.blob_id)
    ghost = latest + 7  # a version no writer has published
    free_before = store.free_slots
    key = chain_hash(chain_hash(0, (1, 2, 3, 4)), (9, 9, 9, 9))
    with pytest.raises(ValueError, match="not yet published"):
        store.register_prefix(key, seq.slots[0], ghost)
    # the failed registration rolled its slot reference back
    assert store.free_slots == free_before
    assert key not in cluster.page_directory
    # nor can any session read at that version
    with pytest.raises(ValueError, match="not yet published"):
        cluster.session().read_pages(store.blob_id, ghost, [0])
    # and a page that was never published is simply invisible: the tail page
    # of this prompt exists only in the owner's pool, so a second client
    # resolves only the PUBLISHED prefix
    other = BlobKVClient(store)
    seq2, shared2, _ = other.admit([1, 2, 3, 4, 9, 9, 9, 9])
    assert shared2 == 4
    other.finish(seq2)
    client.finish(seq)
    cluster.close()


# ----------------------- slot reuse under pins/refs ------------------------
def test_directory_ref_blocks_eviction_and_recycled_slot_is_cow_safe():
    cluster = make_cluster()
    store = make_store(cluster, n_pages=4)
    a = BlobKVClient(store)
    prompt = [1, 2, 3, 4]
    seq = publish_prompt(a, prompt, fill=20)
    slot = seq.slots[0]
    old_addr = seq.page_addr[0]
    a.finish(seq)
    # the directory's reference alone keeps the slot off the free list
    assert store.used_slots == 1
    b = BlobKVClient(store)
    seq_b, shared, _ = b.admit(prompt)
    assert shared == 4 and seq_b.slots == [slot]
    # an entry a live sequence reads through is not evictable
    assert cluster.page_directory.evict_unreferenced(1, blob_id=store.blob_id) == 0
    b.finish(seq_b)
    # unreferenced now: eviction frees the slot
    assert cluster.page_directory.evict_unreferenced(1, blob_id=store.blob_id) == 1
    assert store.used_slots == 0
    # pin the OLD version, then republish the recycled slot with new bytes:
    # the new registration carries a strictly higher version and the pinned
    # old version still reads the old bytes (blob writes are COW — a reused
    # slot can never clobber what an older version's readers see)
    cluster.pin_published(store.blob_id, old_addr.version)
    seq2 = publish_prompt(a, [9, 9, 9, 9], fill=77)
    assert seq2.slots == [slot]  # recycled
    assert seq2.page_addr[0].version > old_addr.version
    old = a.session.read_pages(
        store.blob_id, old_addr.version, [old_addr.page], pinned=True
    )[0]
    np.testing.assert_array_equal(old, page_payload(store, 20))
    np.testing.assert_array_equal(
        a.fetch_pages([seq2.page_addr[0]])[0], page_payload(store, 77)
    )
    cluster.unpin_version(store.blob_id, old_addr.version)
    a.finish(seq2)
    cluster.close()


# ------------------------------ pool pressure ------------------------------
def test_pool_pressure_evicts_directory_then_memoryerror_then_reuse():
    cluster = make_cluster()
    store = make_store(cluster, n_pages=4)
    client = BlobKVClient(store)
    # fill the pool with finished, directory-advertised prefix pages
    for i in range(4):
        seq = publish_prompt(client, [i, i + 1, i + 2, i + 3], fill=i)
        client.finish(seq)
    assert store.free_slots == 0 and len(cluster.page_directory) == 4
    # pressure: a fresh admit reclaims unreferenced directory entries
    seq, shared, _ = client.admit([50, 51, 52, 53, 54])  # needs 2 slots
    assert shared == 0 and len(seq.slots) == 2
    assert store.stats["evictions"] >= 2
    hold, _, _ = client.admit([60, 61, 62, 63])  # evicts another entry
    hold2, _, _ = client.admit([65, 66, 67, 68])  # evicts the last entry
    # everything referenced by live sequences now: admission must fail ...
    with pytest.raises(MemoryError):
        client.admit([70, 71, 72, 73])
    # ... with every partial acquisition rolled back
    assert store.free_slots == 0
    # post-eviction reuse: finishing a sequence frees its slot for admission
    client.finish(hold)
    seq3, _, _ = client.admit([70, 71, 72, 73])
    assert len(seq3.slots) == 1
    client.finish(seq3)
    client.finish(hold2)
    client.finish(seq)
    cluster.close()


def test_failed_admit_releases_shared_prefix_refs():
    """A MemoryError admit that already resolved shared pages must drop its
    directory refs — otherwise the entries become permanently unevictable."""
    cluster = make_cluster()
    store = make_store(cluster, n_pages=2)
    client = BlobKVClient(store)
    seq = publish_prompt(client, [1, 2, 3, 4], fill=4)
    client.finish(seq)  # slot survives via the directory
    hold, _, _ = client.admit([5, 6, 7, 8])  # takes the last free slot
    with pytest.raises(MemoryError):
        # shares the published page, then fails allocating its tail page
        client.admit([1, 2, 3, 4, 9, 9])
    # the rollback released the directory ref: the entry is evictable again
    assert cluster.page_directory.evict_unreferenced(1, blob_id=store.blob_id) == 1
    seq2, _, _ = client.admit([9, 9, 9, 9])
    client.finish(seq2)
    client.finish(hold)
    cluster.close()


# ----------------------- hot prefixes drive the balancer --------------------
def test_hot_prefix_drives_replica_promotion_through_blob_path():
    """The ROADMAP's realistic-skew story: N sessions hammering one shared
    prefix page (no cache tiers) is exactly the hot-page pattern the
    ReplicaBalancer promotes on — through the real blob fetch path."""
    cluster = make_cluster(
        n_data_providers=8,
        balancer_config=BalancerConfig(
            hot_threshold=4, skew_ratio=1.2, check_interval=16
        ),
    )
    store = make_store(cluster, n_pages=8)
    writer = BlobKVClient(store, session=cluster.session(cache_bytes=0))
    seq = publish_prompt(writer, [1, 2, 3, 4], fill=5)
    reader = BlobKVClient(store, session=cluster.session(cache_bytes=0))
    addr = seq.page_addr[0]
    for _ in range(200):
        reader.fetch_pages([addr])
    bal = cluster.replica_balancer
    assert bal is not None
    assert (bal.promotions or bal.rebalance()) > 0
    writer.finish(seq)
    cluster.close()


# --------------------------------- GC safety --------------------------------
def test_gc_honors_directory_pins():
    cluster = make_cluster()
    store = make_store(cluster, n_pages=4)
    client = BlobKVClient(store)
    seq = publish_prompt(client, [1, 2, 3, 4], fill=8)
    client.finish(seq)  # only the directory pin protects this version now
    seq2 = publish_prompt(client, [5, 6, 7, 8], fill=9)
    latest = cluster.version_manager.latest_published(store.blob_id)
    cluster.gc(store.blob_id, keep_versions=[latest])
    # the directory-advertised page survived GC: still resolves AND reads
    reader = BlobKVClient(store)
    got, shared, fetches = reader.admit([1, 2, 3, 4])
    assert shared == 4
    np.testing.assert_array_equal(
        reader.fetch_pages([a for _, a in fetches])[0], page_payload(store, 8)
    )
    reader.finish(got)
    client.finish(seq2)
    cluster.close()


# --------------------------- the serving CI gate ----------------------------
def test_compare_gates_serving_payload():
    import benchmarks.compare as compare

    old = {"git_rev": "aaa", "rows": [
        {"mode": "shared", "sessions": 2, "tok_per_s": 1000.0},
        {"mode": "private", "sessions": 2, "tok_per_s": 500.0},
    ]}
    new = {"git_rev": "bbb", "rows": [
        {"mode": "shared", "sessions": 2, "tok_per_s": 600.0},   # -40%
        {"mode": "private", "sessions": 2, "tok_per_s": 490.0},  # -2%
        {"mode": "shared", "sessions": 4, "tok_per_s": 900.0},   # new cell
    ]}
    regs = compare.regressions(
        old, new, 30.0, metric="tok_per_s", count_key="sessions"
    )
    assert [key for key, _ in regs] == [("shared", 2)]
    lines = compare.diff_rows(old, new, metric="tok_per_s", count_key="sessions")
    assert any(l.startswith("shared,4") and l.endswith("new") for l in lines)
    assert not compare.regressions(
        old, new, 50.0, metric="tok_per_s", count_key="sessions"
    )
