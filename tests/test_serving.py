"""Serving engine integration: continuous batching, prefix-cache sharing,
COW correctness, output equivalence with single-request decode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.lm import build_model
from repro.serving.engine import Request, ServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("llama3_2-1b").smoke()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def reference_generate(cfg, model, params, prompt, n_new):
    """Oracle: plain prefill + decode, no engine, no paging tricks shared."""
    pad = (-len(prompt)) % cfg.kv_page_tokens
    toks = jnp.asarray(list(prompt) + [0] * pad, jnp.int32)[None]
    logits, cache = model.prefill(params, {"tokens": toks}, None)
    out = [int(np.argmax(np.asarray(logits)[0][: cfg.vocab_size]))]
    for _ in range(n_new - 1):
        logits, cache = model.decode_step(
            params, cache, jnp.asarray([out[-1]], jnp.int32), None
        )
        out.append(int(np.argmax(np.asarray(logits)[0][: cfg.vocab_size])))
    return out


def test_engine_matches_reference_single(setup):
    cfg, model, params = setup
    prompt = [5, 7, 11, 13, 17, 19, 23, 29]  # one full page (T=8)
    engine = ServingEngine(cfg, params, max_slots=2, n_pages=64)
    engine.submit(Request(0, prompt, max_new_tokens=6))
    done = engine.run_until_drained()
    want = reference_generate(cfg, model, params, prompt, 6)
    assert done[0].tokens == want


def test_engine_concurrent_requests_isolated(setup):
    """Two different prompts decoded concurrently must match their solo runs
    (no cross-request page interference — W/W isolation)."""
    cfg, model, params = setup
    p1 = [5, 7, 11, 13, 17, 19, 23, 29]
    p2 = [2, 3, 4, 6, 8, 9, 10, 12]
    engine = ServingEngine(cfg, params, max_slots=4, n_pages=64)
    engine.submit(Request(0, p1, max_new_tokens=5))
    engine.submit(Request(1, p2, max_new_tokens=5))
    done = engine.run_until_drained()
    assert done[0].tokens == reference_generate(cfg, model, params, p1, 5)
    assert done[1].tokens == reference_generate(cfg, model, params, p2, 5)


def test_prefix_cache_shares_pages_and_stays_correct(setup):
    """Second request with the same full-page prefix reuses pages (space
    saving) and still decodes exactly like its solo run (COW correctness)."""
    cfg, model, params = setup
    prefix = [5, 7, 11, 13, 17, 19, 23, 29]  # one full page
    pa = prefix + [31, 37, 41, 43, 47, 53, 59, 61]
    pb = prefix + [1, 2, 3, 4, 5, 6, 7, 8]
    engine = ServingEngine(cfg, params, max_slots=4, n_pages=64)
    engine.submit(Request(0, pa, max_new_tokens=4))
    done = engine.run_until_drained()
    engine.submit(Request(1, pb, max_new_tokens=4))
    done2 = engine.run_until_drained()
    assert done2[1].prefill_skipped_tokens == len(prefix)  # page shared
    assert done[0].tokens == reference_generate(cfg, model, params, pa, 4)
    assert done2[1].tokens == reference_generate(cfg, model, params, pb, 4)


def test_backpressure_pool_exhaustion(setup):
    """More requests than pages: engine admits what fits, drains, then admits
    the rest — nothing deadlocks, everything completes."""
    cfg, model, params = setup
    engine = ServingEngine(cfg, params, max_slots=2, n_pages=12)
    for i in range(5):
        prompt = [i + 1] * 8
        engine.submit(Request(i, prompt, max_new_tokens=3))
    done = engine.run_until_drained()
    assert len(done) == 5