"""Serving engine integration: continuous batching, prefix-cache sharing,
COW correctness, output equivalence with single-request decode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import Cluster
from repro.models.lm import build_model
from repro.serving.blob_kv import BlobKVClient, BlobKVStore
from repro.serving.engine import Request, ServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("llama3_2-1b").smoke()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def reference_generate(cfg, model, params, prompt, n_new):
    """Oracle: plain prefill + decode, no engine, no paging tricks shared."""
    pad = (-len(prompt)) % cfg.kv_page_tokens
    toks = jnp.asarray(list(prompt) + [0] * pad, jnp.int32)[None]
    logits, cache = model.prefill(params, {"tokens": toks}, None)
    out = [int(np.argmax(np.asarray(logits)[0][: cfg.vocab_size]))]
    for _ in range(n_new - 1):
        logits, cache = model.decode_step(
            params, cache, jnp.asarray([out[-1]], jnp.int32), None
        )
        out.append(int(np.argmax(np.asarray(logits)[0][: cfg.vocab_size])))
    return out


def test_engine_matches_reference_single(setup):
    cfg, model, params = setup
    prompt = [5, 7, 11, 13, 17, 19, 23, 29]  # one full page (T=8)
    engine = ServingEngine(cfg, params, max_slots=2, n_pages=64)
    engine.submit(Request(0, prompt, max_new_tokens=6))
    done = engine.run_until_drained()
    want = reference_generate(cfg, model, params, prompt, 6)
    assert done[0].tokens == want


def test_engine_concurrent_requests_isolated(setup):
    """Two different prompts decoded concurrently must match their solo runs
    (no cross-request page interference — W/W isolation)."""
    cfg, model, params = setup
    p1 = [5, 7, 11, 13, 17, 19, 23, 29]
    p2 = [2, 3, 4, 6, 8, 9, 10, 12]
    engine = ServingEngine(cfg, params, max_slots=4, n_pages=64)
    engine.submit(Request(0, p1, max_new_tokens=5))
    engine.submit(Request(1, p2, max_new_tokens=5))
    done = engine.run_until_drained()
    assert done[0].tokens == reference_generate(cfg, model, params, p1, 5)
    assert done[1].tokens == reference_generate(cfg, model, params, p2, 5)


def test_prefix_cache_shares_pages_and_stays_correct(setup):
    """Second request with the same full-page prefix reuses pages (space
    saving) and still decodes exactly like its solo run (COW correctness)."""
    cfg, model, params = setup
    prefix = [5, 7, 11, 13, 17, 19, 23, 29]  # one full page
    pa = prefix + [31, 37, 41, 43, 47, 53, 59, 61]
    pb = prefix + [1, 2, 3, 4, 5, 6, 7, 8]
    engine = ServingEngine(cfg, params, max_slots=4, n_pages=64)
    engine.submit(Request(0, pa, max_new_tokens=4))
    done = engine.run_until_drained()
    engine.submit(Request(1, pb, max_new_tokens=4))
    done2 = engine.run_until_drained()
    assert done2[1].prefill_skipped_tokens == len(prefix)  # page shared
    assert done[0].tokens == reference_generate(cfg, model, params, pa, 4)
    assert done2[1].tokens == reference_generate(cfg, model, params, pb, 4)


def test_partial_page_prefix_reuse_matches_no_sharing(setup):
    """Prompts ending inside a live donor's partial page are fully shared via
    a COW fork — and decode exactly like runs that never reused anything (the
    stale donor positions stay masked until overwritten). The oracle here is
    a no-reuse engine, not ``reference_generate``: engine and raw-decode
    padding semantics already differ for non-page-aligned prompts."""
    cfg, model, params = setup
    page = [5, 7, 11, 13, 17, 19, 23, 29]  # one full page (T=8)
    prompt = page + [31, 37, 41]  # ends inside page 1
    shorter = page + [31, 37]  # a strict prefix of the donor's tail
    # baselines decoded without any partial-page reuse (donors die between
    # drains, so only the established full-page sharing path is exercised)
    base = ServingEngine(cfg, params, max_slots=4, n_pages=64)
    base.submit(Request(0, prompt, max_new_tokens=4))
    want_prompt = base.run_until_drained()[0].tokens
    base.submit(Request(1, shorter, max_new_tokens=4))
    want_shorter = base.run_until_drained()[1].tokens
    assert base.alloc.stats["cow_copies"] == 0

    engine = ServingEngine(cfg, params, max_slots=4, n_pages=64)
    engine.submit(Request(0, prompt, max_new_tokens=4))
    engine.submit(Request(1, prompt, max_new_tokens=4))  # admitted while 0 lives
    engine.submit(Request(2, shorter, max_new_tokens=4))
    done = engine.run_until_drained()
    assert done[1].prefill_skipped_tokens == len(prompt)
    assert done[2].prefill_skipped_tokens == len(shorter)
    assert engine.alloc.stats["cow_copies"] >= 2
    assert engine.alloc.stats["partial_shared_tokens"] >= 5
    assert done[0].tokens == want_prompt
    assert done[1].tokens == want_prompt
    assert done[2].tokens == want_shorter


def test_blob_engine_matches_reference_and_shares_across_engines(setup):
    """Blob mode: the KV pool lives on a Cluster blob. A single request
    matches the oracle, and a SECOND engine (own session + device pool)
    resolves the shared prefix through the cluster directory, fetches the
    published bytes instead of re-storing them, and still decodes exactly."""
    cfg, model, params = setup
    cluster = Cluster(n_data_providers=2, n_metadata_providers=2)
    store = BlobKVStore.for_kv(
        cluster, n_pages=64, page_tokens=cfg.kv_page_tokens,
        n_layers=cfg.n_layers, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim, dtype=np.dtype("uint16"),  # bf16 payloads
    )
    prefix = [5, 7, 11, 13, 17, 19, 23, 29]
    pa = prefix + [31, 37, 41, 43, 47, 53, 59, 61]
    pb = prefix + [1, 2, 3, 4, 5, 6, 7, 8]
    engine_a = ServingEngine(cfg, params, max_slots=2,
                             kv_client=BlobKVClient(store))
    engine_a.submit(Request(0, pa, max_new_tokens=4))
    done_a = engine_a.run_until_drained()
    assert done_a[0].tokens == reference_generate(cfg, model, params, pa, 4)
    used = store.used_slots
    engine_b = ServingEngine(cfg, params, max_slots=2,
                             kv_client=BlobKVClient(store))
    engine_b.submit(Request(1, pb, max_new_tokens=4))
    done_b = engine_b.run_until_drained()
    assert done_b[1].prefill_skipped_tokens == len(prefix)
    assert done_b[1].tokens == reference_generate(cfg, model, params, pb, 4)
    # the shared prefix page was not stored twice
    assert store.stats["prefix_hits"] >= 1
    assert store.used_slots <= used + 1  # only B's fresh tail page persists
    cluster.close()


def test_backpressure_pool_exhaustion(setup):
    """More requests than pages: engine admits what fits, drains, then admits
    the rest — nothing deadlocks, everything completes."""
    cfg, model, params = setup
    engine = ServingEngine(cfg, params, max_slots=2, n_pages=12)
    for i in range(5):
        prompt = [i + 1] * 8
        engine.submit(Request(i, prompt, max_new_tokens=3))
    done = engine.run_until_drained()
    assert len(done) == 5