"""Tests for the skew-aware parallel data plane (PR 2).

Covers: heap-based bulk placement complexity, DataProvider thread-safety,
batched version assignment (journal byte-compatibility with the single-patch
API), interval-indexed traverse_batch equivalence vs the reference traversal,
replica fallback when a provider dies mid-readv, and adaptive hot-page
promotion/demotion.
"""

import threading

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (
    BalancerConfig,
    Cluster,
    DataProvider,
    IntervalIndex,
    NodeKey,
    ProviderManager,
    VersionManager,
    traverse,
    traverse_batch,
)

PAGE = 64


def make_session(**kw):
    session_kw = {
        k: kw.pop(k)
        for k in ("cache_bytes", "replica_spread", "sync_write", "max_inflight_writes")
        if k in kw
    }
    session_kw.setdefault("cache_bytes", 0)
    kw.setdefault("n_data_providers", 8)
    kw.setdefault("n_metadata_providers", 4)
    kw.setdefault("shared_cache_bytes", 0)
    return Cluster(**kw).session(**session_kw)


# --------------------------- placement ---------------------------------------


def test_bulk_allocation_is_heap_not_per_page_sort():
    """16k-page placement must cost O(n·r·log P) heap ops, not a per-page
    full sort (O(n·P) comparisons at minimum)."""
    n_providers, n_pages = 64, 16384
    mgr = ProviderManager(replication=1)
    for i in range(n_providers):
        mgr.register(DataProvider(i))
    mgr.placement_ops = 0
    mgr.allocate(n_pages)
    # 2 ops per page (pop + push) plus slack for stale entries; a per-page
    # sort would have been >= n_pages * n_providers comparisons
    assert mgr.placement_ops <= 4 * n_pages
    assert mgr.placement_ops < n_pages * n_providers


def test_bulk_allocation_stays_balanced_with_replication():
    n_providers = 10
    mgr = ProviderManager(replication=3)
    for i in range(n_providers):
        mgr.register(DataProvider(i))
    out = mgr.allocate(500)
    assert len(out) == 500
    for primary, replicas in out:
        pids = [primary[0]] + [pid for pid, _ in replicas]
        assert len(set(pids)) == 3  # all distinct
        keys = {primary[1]} | {k for _, k in replicas}
        assert len(keys) == 1  # replicas share the page key
    loads = mgr.load_snapshot()
    assert sum(loads.values()) == 500 * 3
    assert max(loads.values()) - min(loads.values()) <= 1  # least-loaded


def test_allocation_balances_after_release_and_churn():
    mgr = ProviderManager(replication=1)
    for i in range(4):
        mgr.register(DataProvider(i))
    first = mgr.allocate(40)
    # free provider 0's pages: it must become the placement target again
    mine = [p for p, _ in first if p[0] == 0]
    mgr.release(mine)
    nxt = mgr.allocate(len(mine))
    assert all(p[0] == 0 for p, _ in nxt)
    mgr.deregister(2)
    out = mgr.allocate(30)
    assert all(p[0] != 2 for p, _ in out)


# ------------------------ provider thread-safety ------------------------------


def test_provider_mutation_concurrent_with_iteration():
    """put_pages/delete_pages racing used_bytes/n_pages must never raise
    "dict changed size during iteration"."""
    provider = DataProvider(0)
    stop = threading.Event()
    errors = []

    def mutator():
        i = 0
        try:
            while not stop.is_set():
                provider.put_pages([(i % 97, np.ones(256, np.uint8))])
                provider.delete_pages([(i + 31) % 97])
                i += 1
        except Exception as e:  # pragma: no cover
            errors.append(e)

    def observer():
        try:
            while not stop.is_set():
                provider.used_bytes()
                provider.n_pages
        except Exception as e:  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=mutator) for _ in range(2)] + [
        threading.Thread(target=observer) for _ in range(2)
    ]
    for t in threads:
        t.start()
    timer = threading.Timer(1.0, stop.set)
    timer.start()
    for t in threads:
        t.join()
    timer.cancel()
    assert not errors


# ------------------------ batched version assignment --------------------------


def test_assign_versions_matches_assign_version_loop():
    """Batch assignment must produce the same versions, links and journal as
    the equivalent loop of single assignments."""
    spans = [(0, 4), (2, 3), (6, 2), (0, 8)]
    vm_batch, vm_loop = VersionManager(), VersionManager()
    b1 = vm_batch.alloc(8, PAGE)
    b2 = vm_loop.alloc(8, PAGE)
    got_batch = vm_batch.assign_versions(b1, spans)
    got_loop = [vm_loop.assign_version(b2, o, s) for o, s in spans]
    assert got_batch == got_loop
    assert vm_batch.journal == vm_loop.journal


def test_recover_replays_batch_assigned_journal():
    """Journal produced through writev's batch assignment must replay through
    VersionManager.recover exactly like the single-patch journal (regression
    for the thin-wrapper guarantee)."""
    sess = make_session()
    handle = sess.create(16 * PAGE, PAGE)
    blob = handle.blob_id
    handle.writev(
        [
            (0, np.full(2 * PAGE, 1, np.uint8)),
            (4 * PAGE, np.full(2 * PAGE, 2, np.uint8)),
            (2 * PAGE, np.full(4 * PAGE, 3, np.uint8)),
        ],
    )
    vm = sess.cluster.version_manager
    journal = vm.journal
    assert [e.op for e in journal] == ["alloc"] + ["assign"] * 3 + ["complete"] * 3
    vm2, orphans = VersionManager.recover(journal)
    assert vm2.latest_published(blob) == 3
    assert orphans[blob] == []
    for v in (1, 2, 3):
        assert vm2.interval_of(blob, v) == vm.interval_of(blob, v)
    sess.cluster.close()


def test_writev_takes_manager_lock_once_for_all_patches(monkeypatch):
    sess = make_session()
    handle = sess.create(16 * PAGE, PAGE)
    calls = []
    vm = sess.cluster.version_manager
    orig = vm.assign_versions

    def counting(blob_id, spans):
        calls.append(list(spans))
        return orig(blob_id, spans)

    monkeypatch.setattr(vm, "assign_versions", counting)
    handle.writev(
        [(0, np.ones(PAGE, np.uint8)), (8 * PAGE, np.ones(2 * PAGE, np.uint8))],
    )
    assert calls == [[(0, 1), (8, 2)]]  # ONE batched call for both patches
    sess.cluster.close()


# ------------------------- interval index + traversal -------------------------


def test_interval_index_queries():
    idx = IntervalIndex([(10, 5), (3, 2), (14, 4), (30, 1)])
    # merged: [3,5) [10,18) [30,31)
    assert idx.starts == [3, 10, 30]
    assert idx.ends == [5, 18, 31]
    assert idx.intersects_any(0, 3) is False
    assert idx.intersects_any(4, 1) is True
    assert idx.intersects_any(5, 5) is False
    assert idx.intersects_any(17, 10) is True
    assert idx.intersects_any(31, 100) is False
    assert list(idx.clip(0, 100)) == [(3, 5), (10, 18), (30, 31)]
    assert list(idx.clip(4, 8)) == [(4, 5), (10, 12)]
    assert list(idx.clip(5, 5)) == []


@st.composite
def range_sets(draw):
    total_pages = draw(st.sampled_from([8, 16, 32, 64]))
    n_writes = draw(st.integers(min_value=0, max_value=6))
    writes = []
    for _ in range(n_writes):
        off = draw(st.integers(min_value=0, max_value=total_pages - 1))
        size = draw(st.integers(min_value=1, max_value=total_pages - off))
        writes.append((off, size))
    n_ranges = draw(st.integers(min_value=1, max_value=8))
    ranges = []
    for _ in range(n_ranges):
        off = draw(st.integers(min_value=0, max_value=total_pages - 1))
        size = draw(st.integers(min_value=0, max_value=total_pages - off))
        ranges.append((off, size))
    return total_pages, writes, ranges


@settings(max_examples=40, deadline=None)
@given(range_sets())
def test_traverse_batch_equivalent_to_traverse(case):
    """Property: for ANY write history and ANY randomized range set, the
    interval-indexed batch traversal returns exactly the union of what the
    reference single-range traversal yields per range."""
    total_pages, writes, ranges = case
    sess = make_session(n_data_providers=4)
    handle = sess.create(total_pages * PAGE, PAGE)
    blob = handle.blob_id
    for i, (off, size) in enumerate(writes):
        handle.write(np.full(size * PAGE, (i % 250) + 1, np.uint8), off * PAGE)
    version = handle.latest_published()
    metadata = sess.cluster.metadata

    batch = traverse_batch(
        metadata.get_nodes, blob, version, total_pages, ranges
    )
    expected = {}
    for off, size in ranges:
        if size == 0:
            continue
        for page, leaf in traverse(
            metadata.get_node, blob, version, total_pages, off, size
        ):
            expected[page] = leaf
    assert set(batch) == set(expected)
    for page in expected:
        if expected[page] is None:
            assert batch[page] is None
        else:
            assert batch[page] is not None
            assert batch[page].key == expected[page].key
    sess.cluster.close()


# ----------------------- replica fallback / promotion -------------------------


def test_readv_replica_fallback_when_provider_dies_mid_read():
    """A provider failing between the metadata traversal and the page fetch
    must be survived through replicas (the batch fails, per-page fallback
    succeeds). ``replica_spread=False`` pins fetches to the primary, so
    killing a leaf's primary deterministically exercises the fallback."""
    sess = make_session(
        n_data_providers=4, page_replication=2, replica_spread=False
    )
    cluster = sess.cluster
    handle = sess.create(8 * PAGE, PAGE)
    payload = np.arange(8 * PAGE, dtype=np.uint8)
    handle.write(payload, 0)

    real_traverse = traverse_batch
    killed = []

    def killing_get_nodes(keys):
        got = cluster.metadata.get_nodes(keys)
        if not killed and any(k.size == 1 for k in got):
            # some leaves resolved: kill a primary before pages are fetched
            leaf = next(n for n in got.values() if n.is_leaf)
            cluster.provider_manager.fail_provider(leaf.page[0])
            killed.append(leaf.page[0])
        return got

    import repro.core.cluster as cluster_mod

    orig = cluster_mod.traverse_batch
    # the stub get_nodes ignores the streaming on_partial hook, so leaves
    # reach the fetch stream only via the level-end on_leaves emission —
    # which happens AFTER killing_get_nodes returned and killed the primary
    cluster_mod.traverse_batch = (
        lambda get_nodes, *a, **kw: real_traverse(killing_get_nodes, *a, **kw)
    )
    try:
        outs = handle.readv([(0, 8 * PAGE)])
    finally:
        cluster_mod.traverse_batch = orig
    assert killed, "test harness never killed a provider"
    np.testing.assert_array_equal(outs[0], payload)
    cluster.close()


def hammer(handle, offset, size, n=200):
    for _ in range(n):
        handle.read(offset, size)


def test_hot_page_promotion_appears_in_all_page_refs_and_spreads_reads():
    sess = make_session(
        n_data_providers=8,
        balancer_config=BalancerConfig(
            hot_threshold=4, skew_ratio=1.2, check_interval=16
        ),
    )
    cluster = sess.cluster
    handle = sess.create(16 * PAGE, PAGE)
    blob = handle.blob_id
    handle.write(np.ones(16 * PAGE, np.uint8), 0)
    cluster.stats.reset()
    hammer(handle, 0, PAGE)
    bal = cluster.replica_balancer
    assert bal.promotions > 0
    leaf = cluster.metadata.get_node(NodeKey(blob, 1, 0, 1))
    assert len(leaf.all_page_refs()) == 1 + bal.promotions
    assert bal.promoted_refs(leaf.key) == leaf.replicas
    # reads actually spread: multiple providers served read bytes
    served = {pid for pid, b in cluster.stats.read_bytes_snapshot().items() if b > 0}
    assert len(served) > 1
    # the promoted copies hold the same immutable bytes
    for pid, key in leaf.all_page_refs():
        np.testing.assert_array_equal(
            cluster.provider_manager.get_provider(pid).get_page(key),
            np.ones(PAGE, np.uint8),
        )
    cluster.close()


def test_hot_page_demotion_restores_primary_only_and_frees_copies():
    sess = make_session(
        n_data_providers=8,
        balancer_config=BalancerConfig(
            hot_threshold=4, skew_ratio=1.2, check_interval=16
        ),
    )
    cluster = sess.cluster
    handle = sess.create(16 * PAGE, PAGE)
    handle.write(np.ones(16 * PAGE, np.uint8), 0)
    hammer(handle, 0, PAGE)
    bal = cluster.replica_balancer
    key = NodeKey(handle.blob_id, 1, 0, 1)
    promoted = bal.promoted_refs(key)
    assert promoted
    dropped = bal.demote(key)
    assert dropped == len(promoted)
    leaf = cluster.metadata.get_node(key)
    assert leaf.replicas == ()
    for pid, page_key in promoted:
        assert not cluster.provider_manager.get_provider(pid).has_page(page_key)
    # the page is still readable from its primary
    np.testing.assert_array_equal(
        handle.read(0, PAGE).data, np.ones(PAGE, np.uint8)
    )
    cluster.close()


def test_promotion_survives_primary_failure_without_write_replication():
    """Adaptive replication gives fault tolerance the write path never paid
    for: page_replication=1, but a promoted hot page survives primary loss."""
    sess = make_session(
        n_data_providers=8,
        balancer_config=BalancerConfig(
            hot_threshold=4, skew_ratio=1.2, check_interval=16
        ),
    )
    cluster = sess.cluster
    handle = sess.create(16 * PAGE, PAGE)
    handle.write(np.full(16 * PAGE, 7, np.uint8), 0)
    hammer(handle, 0, PAGE)
    leaf = cluster.metadata.get_node(NodeKey(handle.blob_id, 1, 0, 1))
    assert len(leaf.all_page_refs()) > 1
    cluster.provider_manager.fail_provider(leaf.page[0])
    np.testing.assert_array_equal(
        handle.read(0, PAGE).data, np.full(PAGE, 7, np.uint8)
    )
    cluster.close()


def test_gc_demotes_and_forgets_promoted_pages():
    sess = make_session(
        n_data_providers=8,
        balancer_config=BalancerConfig(
            hot_threshold=4, skew_ratio=1.2, check_interval=16
        ),
    )
    cluster = sess.cluster
    handle = sess.create(16 * PAGE, PAGE)
    handle.write(np.ones(16 * PAGE, np.uint8), 0)  # v1
    hammer(handle, 0, PAGE)
    bal = cluster.replica_balancer
    key = NodeKey(handle.blob_id, 1, 0, 1)
    n_promoted = len(bal.promoted_refs(key))
    assert n_promoted > 0
    promoted = bal.promoted_refs(key)
    handle.write(np.full(16 * PAGE, 2, np.uint8), 0)  # v2 rewrites all
    nodes_freed, pages_freed = cluster.gc(handle.blob_id, keep_versions=[2])
    # v1's 16 pages die, including the promoted copies of the hot page
    assert pages_freed == 16 + n_promoted
    assert bal.promoted_refs(key) == ()
    for pid, page_key in promoted:
        assert not cluster.provider_manager.get_provider(pid).has_page(page_key)
    cluster.close()


def test_repromotion_after_demote_never_resurrects_dropped_refs():
    """Regression: a reader holding a pre-demotion node must not leak the
    dropped replica refs back into the metadata DHT via the balancer's heat
    records — every ref published after re-promotion must point to a live
    page copy."""
    sess = make_session(
        n_data_providers=8,
        balancer_config=BalancerConfig(
            hot_threshold=4, skew_ratio=1.2, check_interval=16
        ),
    )
    cluster = sess.cluster
    handle = sess.create(16 * PAGE, PAGE)
    handle.write(np.ones(16 * PAGE, np.uint8), 0)
    key = NodeKey(handle.blob_id, 1, 0, 1)
    bal = cluster.replica_balancer
    hammer(handle, 0, PAGE)
    assert bal.promoted_refs(key)
    bal.demote(key)
    hammer(handle, 0, PAGE)  # heat builds again: re-promotion allowed
    leaf = cluster.metadata.get_node(key)
    for pid, page_key in leaf.all_page_refs():
        assert cluster.provider_manager.get_provider(pid).has_page(page_key), (
            f"leaf publishes dead ref ({pid}, {page_key})"
        )
    cluster.close()


def test_promotion_skips_failed_target_providers():
    """Regression: a failed cold provider must not be picked as the promotion
    target (that would silently block promotion cluster-wide)."""
    sess = make_session(
        n_data_providers=8,
        balancer_config=BalancerConfig(
            hot_threshold=4, skew_ratio=1.2, check_interval=16
        ),
    )
    cluster = sess.cluster
    handle = sess.create(16 * PAGE, PAGE)
    handle.write(np.ones(16 * PAGE, np.uint8), 0)
    leaf = cluster.metadata.get_node(NodeKey(handle.blob_id, 1, 0, 1))
    # fail every provider except the hot page's primary and one target
    alive_target = next(
        p.provider_id
        for p in cluster.provider_manager.providers()
        if p.provider_id != leaf.page[0]
    )
    for p in cluster.provider_manager.providers():
        if p.provider_id not in (leaf.page[0], alive_target):
            cluster.provider_manager.fail_provider(p.provider_id)
    hammer(handle, 0, PAGE)
    bal = cluster.replica_balancer
    assert bal.promotions >= 1
    assert all(pid == alive_target for pid, _ in bal.promoted_refs(leaf.key))
    cluster.close()


def test_replica_spread_off_always_uses_primary():
    sess = make_session(
        n_data_providers=8, page_replication=2, replica_spread=False,
        hot_replicas=False,
    )
    cluster = sess.cluster
    handle = sess.create(8 * PAGE, PAGE)
    handle.write(np.ones(8 * PAGE, np.uint8), 0)
    cluster.stats.reset()
    for _ in range(20):
        handle.read(0, 8 * PAGE)
    served = set(cluster.stats.read_bytes_snapshot())
    primaries = set()
    for p in range(8):
        primaries.add(
            cluster.metadata.get_node(NodeKey(handle.blob_id, 1, p, 1)).page[0]
        )
    assert served == primaries  # replicas never served
    cluster.close()
