"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles,
swept over shapes and dtypes (deliverable (c))."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.paged_attention import paged_attention_pallas

jax.config.update("jax_enable_x64", False)


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ------------------------------ flash attention ------------------------------
FLASH_CASES = [
    # (B, Sq, Sk, H, K, D, causal, window, dtype)
    (1, 128, 128, 4, 4, 64, True, None, jnp.float32),
    (2, 256, 256, 4, 2, 64, True, None, jnp.float32),
    (1, 128, 128, 8, 1, 128, True, None, jnp.bfloat16),
    (2, 128, 128, 4, 4, 32, False, None, jnp.float32),
    (1, 256, 256, 2, 2, 64, True, 64, jnp.float32),  # sliding window
    (1, 512, 512, 2, 1, 64, True, 128, jnp.bfloat16),
    (2, 128, 256, 4, 4, 64, False, None, jnp.float32),  # cross (Sq != Sk)
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_pallas_matches_ref(case):
    B, Sq, Sk, H, K, D, causal, window, dtype = case
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(42), 3)
    q = rand(kq, (B, Sq, H, D), dtype)
    k = rand(kk, (B, Sk, K, D), dtype)
    v = rand(kv, (B, Sk, K, D), dtype)
    got = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 block_q=64, block_k=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


def test_flash_pallas_matches_xla_path():
    B, S, H, K, D = 2, 256, 4, 2, 64
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = rand(kq, (B, S, H, D), jnp.float32), rand(kk, (B, S, K, D), jnp.float32), rand(kv, (B, S, K, D), jnp.float32)
    a = ops.flash_attention(q, k, v, causal=True, q_chunk=64, impl="xla")
    b = flash_attention_pallas(q, k, v, causal=True, block_q=64, block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)


@settings(max_examples=12, deadline=None)
@given(
    bq=st.sampled_from([32, 64, 128]),
    bk=st.sampled_from([32, 64, 128]),
    s=st.sampled_from([128, 256]),
    window=st.sampled_from([None, 32, 100]),
)
def test_flash_pallas_block_size_sweep(bq, bk, s, window):
    """Property: output is block-size invariant."""
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(7), 3)
    q = rand(kq, (1, s, 2, 64), jnp.float32)
    k = rand(kk, (1, s, 2, 64), jnp.float32)
    v = rand(kv, (1, s, 2, 64), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 block_q=bq, block_k=bk, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


# ------------------------------ paged attention ------------------------------
def make_paged(key, B, S, T, K, D, dtype, window=None, extra_pages=1):
    """Build a filled paged cache (via prefill layout) + a fresh query."""
    kk, kv, kq = jax.random.split(key, 3)
    k = rand(kk, (B, S, K, D), dtype)
    v = rand(kv, (B, S, K, D), dtype)
    pool_k, pool_v, tables, page_pos = ops.prefill_into_pages(k, v, T, extra_pages=extra_pages)
    q = rand(kq, (B, K * (D // D) * 4, D), dtype)  # placeholder, replaced by caller
    return k, v, pool_k, pool_v, tables, page_pos


PAGED_CASES = [
    # (B, S, T, H, K, D, window, dtype)
    (2, 64, 8, 4, 4, 64, None, jnp.float32),
    (3, 128, 16, 8, 2, 64, None, jnp.float32),
    (2, 64, 8, 4, 1, 128, None, jnp.bfloat16),
    (2, 128, 16, 4, 4, 32, 48, jnp.float32),  # sliding window
    (1, 256, 32, 2, 2, 64, 100, jnp.bfloat16),
]


@pytest.mark.parametrize("case", PAGED_CASES)
def test_paged_pallas_matches_ref(case):
    B, S, T, H, K, D, window, dtype = case
    key = jax.random.PRNGKey(3)
    kk, kv, kq = jax.random.split(key, 3)
    k = rand(kk, (B, S, K, D), dtype)
    v = rand(kv, (B, S, K, D), dtype)
    pool_k, pool_v, tables, page_pos = ops.prefill_into_pages(k, v, T)
    q = rand(kq, (B, H, D), dtype)
    lengths = jnp.full((B,), S, jnp.int32)

    o, m, l = paged_attention_pallas(
        q, pool_k, pool_v, tables, page_pos, lengths, window=window, interpret=True
    )
    got = o / np.maximum(np.asarray(l)[..., None], 1e-30)
    want = ref.paged_attention_ref(q, pool_k, pool_v, tables, page_pos, lengths, window=window)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("case", PAGED_CASES[:3])
def test_paged_xla_matches_ref(case):
    B, S, T, H, K, D, window, dtype = case
    key = jax.random.PRNGKey(5)
    kk, kv, kq = jax.random.split(key, 3)
    k = rand(kk, (B, S, K, D), dtype)
    v = rand(kv, (B, S, K, D), dtype)
    pool_k, pool_v, tables, page_pos = ops.prefill_into_pages(k, v, T)
    q = rand(kq, (B, H, D), dtype)
    lengths = jnp.full((B,), S, jnp.int32)
    got = ops.paged_attention(q, pool_k, pool_v, tables, page_pos, lengths,
                              window=window, impl="xla")
    want = ref.paged_attention_ref(q, pool_k, pool_v, tables, page_pos, lengths, window=window)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_paged_split_k_combine_across_shards():
    """Simulate the pool sharded in two halves: combined partials must equal
    the unsharded result (the shard_map split-K correctness)."""
    B, S, T, H, K, D = 2, 128, 8, 4, 2, 64
    key = jax.random.PRNGKey(9)
    kk, kv, kq = jax.random.split(key, 3)
    k = rand(kk, (B, S, K, D), jnp.float32)
    v = rand(kv, (B, S, K, D), jnp.float32)
    pool_k, pool_v, tables, page_pos = ops.prefill_into_pages(k, v, T)
    q = rand(kq, (B, H, D), jnp.float32)
    lengths = jnp.full((B,), S, jnp.int32)
    P = pool_k.shape[0]
    half = P // 2

    parts = []
    for off in (0, half):
        o, m, l = ops._paged_local_xla(
            q, pool_k[off : off + half], pool_v[off : off + half],
            tables, page_pos, lengths, window=None, page_offset=off,
            n_pages_total=P,
        )
        parts.append((o, m, l))
    o = ref.online_softmax_combine(
        jnp.stack([p[0] for p in parts]),
        jnp.stack([p[1] for p in parts]),
        jnp.stack([p[2] for p in parts]),
    )
    want = ref.paged_attention_ref(q, pool_k, pool_v, tables, page_pos, lengths)
    np.testing.assert_allclose(np.asarray(o), np.asarray(want), rtol=3e-5, atol=3e-5)


def test_paged_update_then_attend_ring_rollover():
    """SWA ring: after the ring wraps, attention must see exactly the last
    `window` tokens."""
    B, T, K, D, H = 1, 4, 2, 32, 4
    window = 8
    R = window // T + 1  # 3 ring pages
    pool_k = jnp.zeros((B * R, T, K, D), jnp.float32)
    pool_v = jnp.zeros((B * R, T, K, D), jnp.float32)
    tables = jnp.arange(B * R, dtype=jnp.int32).reshape(B, R)
    page_pos = (jnp.arange(R, dtype=jnp.int32) * T)[None]
    ks, vs = [], []
    key = jax.random.PRNGKey(11)
    for t in range(14):  # wraps the 3-page ring
        key, k1, k2 = jax.random.split(key, 3)
        nk = rand(k1, (B, K, D), jnp.float32)
        nv = rand(k2, (B, K, D), jnp.float32)
        ks.append(nk)
        vs.append(nv)
        pool_k, pool_v, page_pos = ops.paged_update(
            pool_k, pool_v, tables, page_pos, jnp.full((B,), t, jnp.int32), nk, nv
        )
    q = rand(jax.random.PRNGKey(12), (B, H, D), jnp.float32)
    lengths = jnp.full((B,), 14, jnp.int32)
    got = ops.paged_attention(q, pool_k, pool_v, tables, page_pos, lengths,
                              window=window, impl="xla")
    # oracle: plain attention over the last `window` tokens
    k_all = jnp.stack(ks, axis=1)  # (B, 14, K, D)
    v_all = jnp.stack(vs, axis=1)
    out = ref.attention_ref(q[:, None].reshape(B, 1, H, D), k_all[:, -window:],
                            v_all[:, -window:], causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(out[:, 0]), rtol=3e-5, atol=3e-5)

def test_paged_attention_int8_close_to_fp():
    """Int8 per-token-scale KV quantization: decode attention within ~2% of
    the fp reference (the §Perf hillclimb-3 numerics check)."""
    B, S, T, H, K, D = 2, 128, 16, 8, 2, 64
    key = jax.random.PRNGKey(21)
    kk, kv, kq = jax.random.split(key, 3)
    k = rand(kk, (B, S, K, D), jnp.float32)
    v = rand(kv, (B, S, K, D), jnp.float32)
    q = rand(kq, (B, H, D), jnp.float32)
    pool_k, pool_v, tables, page_pos = ops.prefill_into_pages(k, v, T)
    lengths = jnp.full((B,), S, jnp.int32)
    want = ref.paged_attention_ref(q, pool_k, pool_v, tables, page_pos, lengths)

    qk, sk = ops.quantize_token(pool_k)
    qv, sv = ops.quantize_token(pool_v)
    got = ops.paged_attention(q, qk, qv, tables, page_pos, lengths,
                              scale_k=sk, scale_v=sv, impl="xla")
    err = np.abs(np.asarray(got) - np.asarray(want)).max()
    scale = np.abs(np.asarray(want)).max()
    assert err / scale < 0.02, (err, scale)


def test_int8_decode_update_roundtrip():
    """paged_update into an int8 pool: written token is recoverable within
    quantization error."""
    B, T, K, D, R = 2, 8, 2, 32, 4
    pool_k = jnp.zeros((B * R, T, K, D), jnp.int8)
    pool_v = jnp.zeros((B * R, T, K, D), jnp.int8)
    sk = jnp.zeros((B * R, T, K), jnp.float32)
    sv = jnp.zeros((B * R, T, K), jnp.float32)
    tables = jnp.arange(B * R, dtype=jnp.int32).reshape(B, R)
    page_pos = (jnp.arange(R, dtype=jnp.int32) * T)[None].repeat(B, 0)
    nk = rand(jax.random.PRNGKey(1), (B, K, D), jnp.float32)
    nv = rand(jax.random.PRNGKey(2), (B, K, D), jnp.float32)
    lengths = jnp.zeros((B,), jnp.int32)
    pool_k, pool_v, page_pos, sk, sv = ops.paged_update(
        pool_k, pool_v, tables, page_pos, lengths, nk, nv, scale_k=sk, scale_v=sv
    )
    deq = ops.dequantize_pool(pool_k, sk)
    got = np.asarray(deq[tables[:, 0], 0], np.float32)  # (B, K, D) slot 0
    np.testing.assert_allclose(got, np.asarray(nk), atol=np.abs(np.asarray(nk)).max() / 100)
