"""Concurrency tests: the paper's §IV claims, exercised with real threads.

The only serialization point is the version manager; everything else runs in
parallel. These tests drive concurrent readers/writers and assert the paper's
guarantees: serializability, snapshot isolation, in-order publication and
liveness.
"""

import threading

import numpy as np

from repro.core import Cluster

PAGE = 64


def make_cluster():
    return Cluster(
        n_data_providers=8, n_metadata_providers=8, max_workers=16,
        shared_cache_bytes=0,
    )


def test_concurrent_disjoint_writers_all_publish():
    """W/W concurrency (paper §IV.C): concurrent writers to disjoint segments
    all succeed, versions are dense, and the final view merges all patches."""
    cluster = make_cluster()
    n_writers = 8
    handle = cluster.session().create(n_writers * 4 * PAGE, PAGE)
    barrier = threading.Barrier(n_writers)
    errors = []

    def writer(i):
        try:
            barrier.wait()
            buf = np.full(4 * PAGE, i + 1, dtype=np.uint8)
            handle.write(buf, i * 4 * PAGE)
        except Exception as e:  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=writer, args=(i,)) for i in range(n_writers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert handle.latest_published() == n_writers  # liveness
    final = handle.read(0, n_writers * 4 * PAGE).data
    for i in range(n_writers):
        assert (final[i * 4 * PAGE : (i + 1) * 4 * PAGE] == i + 1).all()


def test_concurrent_overlapping_writers_serialize():
    """Overlapping concurrent writes: every published version must equal the
    prefix-application of patches in version order (global serializability).
    Each writer runs its own Session — the paper's N-client topology."""
    cluster = make_cluster()
    blob = cluster.alloc(16 * PAGE, PAGE)
    n_writers = 8
    barrier = threading.Barrier(n_writers)
    log = {}

    def writer(i):
        handle = cluster.session().open(blob)
        barrier.wait()
        fill = i + 1
        buf = np.full(8 * PAGE, fill, dtype=np.uint8)
        off = (i % 3) * 4 * PAGE  # overlapping ranges
        v = handle.write(buf, off)
        log[v] = (off, buf)

    threads = [threading.Thread(target=writer, args=(i,)) for i in range(n_writers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    assert sorted(log) == list(range(1, n_writers + 1))
    reader = cluster.session().open(blob)
    oracle = np.zeros(16 * PAGE, dtype=np.uint8)
    for v in range(1, n_writers + 1):
        off, buf = log[v]
        oracle[off : off + buf.size] = buf
        got = reader.read(0, 16 * PAGE, version=v).data
        np.testing.assert_array_equal(got, oracle, err_msg=f"version {v} diverged")


def test_readers_concurrent_with_writer_see_consistent_snapshots():
    """R/W concurrency (paper §IV.B): readers never observe a torn write —
    each read of version v returns a uniform fill value."""
    cluster = make_cluster()
    handle = cluster.session().create(64 * PAGE, PAGE)
    handle.write(np.full(64 * PAGE, 1, np.uint8), 0)
    stop = threading.Event()
    bad = []

    def reader():
        mine = cluster.session().open(handle.blob_id)
        while not stop.is_set():
            res = mine.read(0, 64 * PAGE)
            vals = np.unique(res.data)
            if len(vals) != 1:  # torn snapshot
                bad.append(vals)

    readers = [threading.Thread(target=reader) for _ in range(4)]
    for t in readers:
        t.start()
    for fill in range(2, 30):
        handle.write(np.full(64 * PAGE, fill, np.uint8), 0)
    stop.set()
    for t in readers:
        t.join()
    assert not bad, f"torn snapshots observed: {bad[:3]}"


def test_publish_order_blocks_until_prefix_completes():
    """In-order publication: v2's success does not publish until v1's does."""
    cluster = make_cluster()
    blob = cluster.alloc(8 * PAGE, PAGE)
    vm = cluster.version_manager
    v1, _ = vm.assign_version(blob, 0, 1)
    v2, _ = vm.assign_version(blob, 4, 1)
    assert (v1, v2) == (1, 2)
    assert vm.report_success(blob, v2) == 0  # still unpublished
    assert vm.latest_published(blob) == 0
    assert vm.report_success(blob, v1) == 2  # both publish together
    assert vm.wait_published(blob, 2, timeout=1.0)


def test_border_precompute_sees_unpublished_concurrent_writes():
    """§IV.C: a writer's border links weave against the latest ASSIGNED
    version (even unpublished), not the latest published one."""
    cluster = make_cluster()
    blob = cluster.alloc(8 * PAGE, PAGE)
    vm = cluster.version_manager
    vm.assign_version(blob, 0, 4)  # v1, in flight (left half)
    _, links = vm.assign_version(blob, 4, 4)  # v2 (right half)
    # v2's root border link (for the left child) must point at v1
    root_links = [l for l in links if (l.offset, l.size) == (0, 8)]
    assert len(root_links) == 1
    assert root_links[0].child_version == 1
