"""Tests for the paged-KV allocator (prefix sharing / COW) and the
incremental blob checkpointer."""

import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Cluster
from repro.storage.checkpoint import BlobCheckpointer
from repro.storage.kvcache import PagedKVAllocator


def make_session(n_data_providers=4, n_metadata_providers=4):
    return Cluster(
        n_data_providers=n_data_providers,
        n_metadata_providers=n_metadata_providers,
        shared_cache_bytes=0,
    ).session()


# ------------------------------- kv allocator -------------------------------
def test_prefix_sharing_shares_full_pages():
    a = PagedKVAllocator(n_pages=64, page_tokens=4)
    p1 = [1, 2, 3, 4, 5, 6, 7, 8, 9]
    seq1, shared1, _ = a.admit(p1)
    assert shared1 == 0
    used_before = a.used_pages()
    # same 8-token prefix -> 2 full pages shared
    seq2, shared2, _ = a.admit([1, 2, 3, 4, 5, 6, 7, 8, 42])
    assert shared2 == 8
    assert seq2.pages[:2] == seq1.pages[:2]
    assert a.used_pages() == used_before + 1  # only the fresh tail page


def test_cow_fork_on_shared_head():
    a = PagedKVAllocator(n_pages=64, page_tokens=4)
    seq1, _, _ = a.admit([1, 2, 3, 4, 5, 6, 7, 8])  # two full pages
    seq2, shared, _ = a.admit([1, 2, 3, 4, 5, 6, 7, 8])  # fully shared
    assert shared == 8
    # seq2 decodes: its head page (page index 2) is fresh -> no copy
    copies = a.append_token(seq2.seq_id)
    assert copies == []
    # rewind case: a third sequence shares, then appends into page 2 which
    # is NOT shared (fresh per admit) -> still no copy
    # force-shared head: snapshot seq1 then decode seq1 beyond its pages
    snap = a.snapshot(seq1.seq_id)
    copies = a.append_token(seq1.seq_id)  # head page 2 freshly allocated
    a.release_snapshot(snap)


def test_cow_copy_when_appending_into_shared_partial_page():
    a = PagedKVAllocator(n_pages=64, page_tokens=4)
    seq1, _, _ = a.admit([1, 2, 3, 4, 5, 6])  # page0 full, page1 partial
    # share page0 only; the diverging tail keeps page1 of seq2 fresh
    seq2, shared, _ = a.admit([1, 2, 3, 4, 9, 9])
    assert shared == 4
    # seq1's partial head page (page1) has ref 1 -> no copy on append
    assert a.append_token(seq1.seq_id) == []
    # snapshot seq1 (retains page1), now appending must COW-fork page1
    snap = a.snapshot(seq1.seq_id)
    copies = a.append_token(seq1.seq_id)
    assert len(copies) == 1
    src, dst = copies[0]
    assert src == snap.pages[1] and dst == a._seqs[seq1.seq_id].pages[1]
    a.release_snapshot(snap)


def test_partial_page_prefix_reuse_populates_cow():
    """admit's third return value (once dead code): a prompt that ENDS inside
    a page matching a live donor's partial final page comes back with a
    (src, dst) fork and the whole prompt counted shared."""
    a = PagedKVAllocator(n_pages=64, page_tokens=4)
    donor, _, _ = a.admit([1, 2, 3, 4, 5, 6, 7])  # page1 partial: (5, 6, 7)
    seq2, shared, cow = a.admit([1, 2, 3, 4, 5, 6, 7])  # identical tail
    assert shared == 7 and len(cow) == 1
    src, dst = cow[0]
    assert src == donor.pages[1] and dst == seq2.pages[1]
    assert dst != src  # a fork, not an alias: appends never hit the donor
    assert a.stats["cow_copies"] >= 1
    assert a.stats["partial_shared_tokens"] == 3
    # a shorter tail that PREFIXES a donor's also forks (stale positions
    # beyond it are masked by length and overwritten by decode)
    seq3, shared3, cow3 = a.admit([1, 2, 3, 4, 5, 6])
    assert shared3 == 6 and len(cow3) == 1
    # a diverging tail gets no reuse
    seq4, shared4, cow4 = a.admit([1, 2, 3, 4, 9, 9])
    assert shared4 == 4 and cow4 == []
    # a tail that SPANS past the donor page gets no partial reuse either
    seq5, shared5, cow5 = a.admit([1, 2, 3, 4, 5, 6, 7, 8, 9])
    assert shared5 == 4 and cow5 == []


def test_partial_donor_entry_dies_with_its_page():
    a = PagedKVAllocator(n_pages=64, page_tokens=4)
    donor, _, _ = a.admit([1, 2, 3, 4, 5, 6, 7])
    a.finish(donor.seq_id)  # frees the partial page -> donor entry must die
    seq2, shared, cow = a.admit([1, 2, 3, 4, 5, 6, 7])
    assert cow == [] and shared == 4  # only the indexed full page shares


def test_finish_releases_pages_and_index_eviction():
    a = PagedKVAllocator(n_pages=8, page_tokens=4)
    seqs = []
    for i in range(3):
        s, _, _ = a.admit([i * 10 + 1, i * 10 + 2, i * 10 + 3, i * 10 + 4])
        seqs.append(s)
    for s in seqs:
        a.finish(s.seq_id)
    # pages remain in the prefix index (cache) but are evictable: admitting
    # new sequences must succeed by evicting cache pages
    for i in range(4):
        a.admit([100 + i, 200 + i, 300 + i, 400 + i, 500 + i])
    assert a.used_pages() <= 8


def test_snapshot_isolation_under_decode():
    """The paper's read/write concurrency: a snapshot's pages survive the
    writer's continued decoding (ref'd), and release frees them."""
    a = PagedKVAllocator(n_pages=16, page_tokens=2)
    seq, _, _ = a.admit([1, 2, 3])
    snap = a.snapshot(seq.seq_id)
    for _ in range(6):
        a.append_token(seq.seq_id)
    assert all(a._ref.get(p, 0) >= 1 for p in snap.pages)
    a.release_snapshot(snap)
    a.finish(seq.seq_id)
    assert a.used_pages() <= len(a._prefix_index) + 1


# ------------------------------- checkpointer -------------------------------
def _tiny_state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "w1": jax.random.normal(k, (64, 64), jnp.float32),
        "w2": jnp.zeros((32,), jnp.float32),
        "step": jnp.array(0, jnp.int32),
    }


def test_checkpoint_roundtrip():
    session = make_session()
    state = _tiny_state()
    ck = BlobCheckpointer(session, state, page_size=4096)
    rec = ck.save(0, state)
    assert rec.dirty_pages > 0
    out = ck.restore(0)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_incremental_checkpoint_writes_only_dirty_pages():
    session = make_session()
    state = _tiny_state()
    ck = BlobCheckpointer(session, state, page_size=4096)
    r0 = ck.save(0, state)
    # identical state -> zero dirty pages (pure COW sharing)
    r1 = ck.save(1, state)
    assert r1.dirty_pages == 0
    # touch one leaf -> only its page(s) rewritten
    state2 = dict(state, w2=state["w2"] + 1.0)
    r2 = ck.save(2, state2)
    assert 0 < r2.dirty_pages < r0.dirty_pages
    # all three checkpoints readable
    w2_old = ck.restore(1)["w2"]
    w2_new = ck.restore(2)["w2"]
    assert float(w2_old[0]) + 1.0 == float(w2_new[0])


def test_checkpoint_crash_consistency():
    """A checkpoint is visible only after completion: reading while a save is
    'in flight' (simulated by unpublished writes) yields the previous one."""
    session = make_session()
    state = _tiny_state()
    ck = BlobCheckpointer(session, state, page_size=4096)
    ck.save(0, state)
    before = ck.restore(0)
    # simulate concurrent reader during a save of new state
    state2 = jax.tree.map(lambda x: x + 1 if x.dtype != jnp.int32 else x, state)
    t = ck.save_async(1, state2)
    got = ck.restore(0)  # reader pinned to step 0 stays consistent
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(before)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    t.join()
    after = ck.restore(1)
    np.testing.assert_array_equal(np.asarray(after["w1"]), np.asarray(state2["w1"]))


def test_checkpoint_gc_retention():
    session = make_session()
    state = _tiny_state()
    ck = BlobCheckpointer(session, state, page_size=4096, keep_last=2)
    for i in range(5):
        state = dict(state, w1=state["w1"] + 1.0)
        ck.save(i, state)
    assert len(ck.checkpoints) == 2
    ck.restore(ck.checkpoints[0].step)
    ck.restore(ck.checkpoints[1].step)


def test_checkpoint_reshard_restore():
    """Elastic restart: restore with explicit shardings onto a CPU mesh."""
    session = make_session(2, 2)
    state = _tiny_state()
    ck = BlobCheckpointer(session, state, page_size=4096)
    ck.save(0, state)
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), state)
    out = ck.restore(0, shardings=sh)
    assert all(x.sharding == NamedSharding(mesh, P()) for x in jax.tree.leaves(out))