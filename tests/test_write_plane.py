"""Pipelined asynchronous write plane + zero-copy transport tests.

Covers the overlapped ``writev`` pipeline (data puts in flight while versions
are assigned and metadata is woven), the bounded ``write_async``/``flush``
window, zero-copy page hand-off on both the write and read paths, write-through
caching, failure cleanup (placement release + orphan deletion + version
abandonment), and the per-destination write-byte accounting.
"""

import threading

import numpy as np
import pytest

from repro.core import Cluster, ProviderFailed, VersionManager
from repro.core.provider import DataProvider

PAGE = 64


def make_session(**kw):
    session_kw = {
        k: kw.pop(k)
        for k in ("cache_bytes", "replica_spread", "sync_write", "max_inflight_writes")
        if k in kw
    }
    kw.setdefault("n_data_providers", 4)
    kw.setdefault("n_metadata_providers", 4)
    kw.setdefault("shared_cache_bytes", 0)
    return Cluster(**kw).session(**session_kw)


def page(fill, nbytes=PAGE):
    return np.full(nbytes, fill, np.uint8)


# ------------------------------ zero-copy -------------------------------------


def test_writev_stores_zero_copy_views_and_freezes_source():
    """No per-page ``.copy()`` on the hot path: providers hold views of the
    writer's buffer, and the buffer is frozen so they can never change."""
    sess = make_session(n_data_providers=1, cache_bytes=0)
    handle = sess.create(8 * PAGE, PAGE)
    buf = np.arange(4 * PAGE, dtype=np.uint8)
    handle.write(buf, 0)
    provider = sess.cluster.provider_manager.get_provider(0)
    stored = [provider.get_page(k) for k in range(4)]
    for pg in stored:
        assert np.shares_memory(pg, buf)  # view, not copy
        assert not pg.flags.writeable
    with pytest.raises(ValueError):
        buf[0] = 99  # the source was surrendered to the store
    sess.cluster.close()


def test_writev_copies_unfreezable_views_once():
    """A view of a larger writable array cannot be protected by freezing
    (writes through the base would mutate the stored pages), so the write
    plane must fall back to a bulk copy — published data stays immutable."""
    sess = make_session(n_data_providers=1)
    handle = sess.create(8 * PAGE, PAGE)
    big = np.zeros(2 * PAGE, np.uint8)
    v = handle.write(big[:PAGE], 0)
    big[0] = 99  # caller mutates the base AFTER publication
    assert handle.read(0, PAGE, version=v).data[0] == 0  # snapshot unharmed
    sess.cluster.close()


def test_buffer_surrender_semantics_on_failure():
    """Validation errors must not freeze anything (no side effects before
    the patch list checks out); once the pipeline launches, the buffer is
    surrendered for good even if the write fails — another overlapping
    write may already hold zero-copy views of the same memory, so an abort
    cannot safely hand writability back."""
    sess = make_session(cache_bytes=0)
    handle = sess.create(8 * PAGE, PAGE)
    buf = np.zeros(4 * PAGE, np.uint8)
    with pytest.raises(ValueError, match="page-aligned"):
        handle.writev([(0, buf), (3, page(1))])
    buf[0] = 1  # a rejected batch froze nothing
    for pid in range(4):
        sess.cluster.provider_manager.fail_provider(pid)
    with pytest.raises(ProviderFailed):
        handle.write(buf, 0)
    assert not buf.flags.writeable  # launched pipeline -> surrendered
    sess.cluster.close()


def test_abort_leaks_hole_version_wreckage_for_later_readers():
    """When a failed writer's version becomes a publication HOLE (a
    concurrent writer was assigned after it), the abort must NOT scrub its
    stored metadata/pages: the later writer's published tree border-links
    into them."""
    sess = make_session(n_data_providers=2, cache_bytes=0)
    cluster = sess.cluster
    cluster.provider_manager.on_dead = None  # scrubbing is RepairService's job
    handle = sess.create(8 * PAGE, PAGE)
    blob = handle.blob_id
    started, release = _blocking_provider(cluster, 0)
    failed = []

    def writer_a():
        try:
            handle.write(page(1), 0)  # page 0 -> provider 0 (blocked)
        except ProviderFailed as err:
            failed.append(err)

    t = threading.Thread(target=writer_a)
    t.start()
    assert started.wait(10)
    for _ in range(200):  # wait until A holds v1
        if cluster.version_manager.assigned_versions(blob) == 1:
            break
        threading.Event().wait(0.01)
    # B runs in its own session, assigned after A
    v2 = cluster.session().open(blob).write(page(2), PAGE)
    assert v2 == 2
    # EVERY provider fails: A's mid-flight re-placement (which would
    # otherwise rescue the write onto provider 1) has no target -> abort
    for pid in (0, 1):
        cluster.provider_manager.fail_provider(pid)
    release.set()
    t.join(10)
    assert failed  # A's data put raised and its writev aborted
    # v1 is a hole: publication passed it, B's version is readable
    assert cluster.version_manager.latest_published(blob) == 2
    # A's metadata (stored mid-pipeline) survives the abort — B's tree
    # border-links into version 1 for the untouched ranges
    from repro.core import NodeKey
    leaked = dict(cluster.metadata.iter_nodes(blob))
    assert NodeKey(blob, 1, 0, 1) in leaked
    # B's own data is readable once its provider rejoins; A's page is
    # genuinely lost (never stored), which is writer-recovery territory —
    # but the metadata spine is intact
    cluster.provider_manager.recover_provider(1)
    np.testing.assert_array_equal(
        handle.read(PAGE, PAGE, version=v2).data, page(2)
    )
    cluster.close()


def test_sync_write_baseline_copies_pages():
    """The pre-pipeline A/B baseline keeps its defensive per-page copies."""
    sess = make_session(n_data_providers=1, cache_bytes=0, sync_write=True)
    handle = sess.create(8 * PAGE, PAGE)
    buf = np.arange(2 * PAGE, dtype=np.uint8)
    handle.write(buf, 0)
    provider = sess.cluster.provider_manager.get_provider(0)
    assert not any(np.shares_memory(provider.get_page(k), buf) for k in range(2))
    sess.cluster.close()


def test_full_page_read_is_zero_copy_view():
    """A read of exactly one whole page returns the stored/cached page itself
    (read-only), not a per-page Python assembly into a fresh buffer."""
    sess = make_session()
    handle = sess.create(8 * PAGE, PAGE)
    handle.write(np.arange(8 * PAGE, dtype=np.uint8), 0)
    a = handle.read(2 * PAGE, PAGE).data
    b = handle.read(2 * PAGE, PAGE).data
    assert np.shares_memory(a, b)  # both are views of the same cached page
    assert not a.flags.writeable
    # unaligned / multi-page segments still assemble into a fresh buffer
    c = handle.read(2 * PAGE + 1, PAGE).data
    assert not np.shares_memory(a, c)
    sess.cluster.close()


def test_full_page_read_of_zero_page_shares_the_zero_buffer():
    sess = make_session()
    handle = sess.create(8 * PAGE, PAGE)
    a = handle.read(0, PAGE).data
    b = handle.read(PAGE, PAGE).data
    assert np.shares_memory(a, b)  # one shared immutable zero page
    assert not a.any()


# --------------------------- write-through cache ------------------------------


def test_write_through_makes_own_rereads_free():
    sess = make_session()
    handle = sess.create(8 * PAGE, PAGE)
    v = handle.write(np.arange(4 * PAGE, dtype=np.uint8), 0)
    stats = sess.cluster.stats
    stats.reset()
    got = handle.read(0, 4 * PAGE, version=v).data
    np.testing.assert_array_equal(got, np.arange(4 * PAGE, dtype=np.uint8))
    assert stats.data_rounds == 0  # no provider round-trips
    assert stats.metadata_rounds == 0  # no tree traversal either
    assert stats.cache_hits == 4
    sess.cluster.close()


# ----------------------------- pipelining -------------------------------------


def _blocking_provider(cluster, pid):
    """Make provider ``pid``'s put_pages block until released; returns
    (started, release) events."""
    provider = cluster.provider_manager.get_provider(pid)
    started, release = threading.Event(), threading.Event()
    real_put = provider.put_pages

    def blocked_put(items):
        started.set()
        assert release.wait(10), "test released too late"
        return real_put(items)

    provider.put_pages = blocked_put
    return started, release


def test_pipelined_writev_overlaps_version_and_metadata_with_data_puts():
    """The tentpole property, asserted structurally: while the data puts are
    still in flight, the version is already assigned AND the metadata nodes
    are already stored. Only report_success waits for the join."""
    sess = make_session(n_data_providers=1, cache_bytes=0)
    cluster = sess.cluster
    handle = sess.create(8 * PAGE, PAGE)
    blob = handle.blob_id
    started, release = _blocking_provider(cluster, 0)
    done = []
    t = threading.Thread(
        target=lambda: done.append(handle.write(page(7, 2 * PAGE), 0))
    )
    t.start()
    try:
        assert started.wait(10)
        # data put is blocked right now, yet the pipeline has moved on:
        vm = cluster.version_manager
        deadline = threading.Event()
        for _ in range(200):
            if vm.assigned_versions(blob) == 1 and cluster.metadata.total_nodes() > 0:
                break
            deadline.wait(0.01)
        assert vm.assigned_versions(blob) == 1  # version assigned mid-put
        assert cluster.metadata.total_nodes() > 0  # metadata stored mid-put
        assert vm.latest_published(blob) == 0  # but success awaits the join
    finally:
        release.set()
        t.join()
    assert done == [1]
    assert cluster.version_manager.latest_published(blob) == 1
    cluster.close()


def test_sync_write_keeps_the_stage_barrier():
    """A/B contrast: with sync_write=True no version is assigned until the
    data puts complete (the pre-pipeline full barrier)."""
    sess = make_session(n_data_providers=1, cache_bytes=0, sync_write=True)
    cluster = sess.cluster
    handle = sess.create(8 * PAGE, PAGE)
    blob = handle.blob_id
    started, release = _blocking_provider(cluster, 0)
    t = threading.Thread(target=lambda: handle.write(page(7), 0))
    t.start()
    try:
        assert started.wait(10)
        threading.Event().wait(0.05)  # give a broken pipeline time to leak
        assert cluster.version_manager.assigned_versions(blob) == 0
        assert cluster.metadata.total_nodes() == 0
    finally:
        release.set()
        t.join()
    assert cluster.version_manager.latest_published(blob) == 1
    cluster.close()


# ------------------------- write_async / flush --------------------------------


def test_write_async_window_applies_backpressure():
    sess = make_session(n_data_providers=1, cache_bytes=0, max_inflight_writes=2)
    handle = sess.create(16 * PAGE, PAGE)
    started, release = _blocking_provider(sess.cluster, 0)
    f1 = handle.write_async(page(1), 0)
    f2 = handle.write_async(page(2), PAGE)  # window now full
    assert started.wait(10)
    third_submitted = threading.Event()

    def third():
        handle.write_async(page(3), 2 * PAGE)
        third_submitted.set()

    t = threading.Thread(target=third)
    t.start()
    assert not third_submitted.wait(0.1)  # blocked on the window
    release.set()
    t.join(10)
    assert third_submitted.is_set()
    assert sorted([f1.result(), f2.result()]) == [1, 2]
    flushed = sess.flush()  # completed-and-pruned writes are not re-reported
    assert 3 in flushed and set(flushed) <= {1, 2, 3}
    assert handle.latest_published() == 3
    sess.cluster.close()


def test_write_async_publishes_in_assignment_order_under_random_service():
    """Satellite: versions publish in assignment order per blob even when
    later writes' data lands first (randomized provider service times),
    across multiple concurrently streaming SESSIONS."""
    cluster = Cluster(
        n_data_providers=6, n_metadata_providers=6, max_workers=24,
        shared_cache_bytes=0,
    )
    rng = np.random.default_rng(7)
    for provider in cluster.provider_manager.providers():
        provider.page_service_seconds = float(rng.uniform(0.0, 0.004))
    blob = cluster.alloc(64 * PAGE, PAGE)
    n_writers, writes_each = 3, 8
    log_lock = threading.Lock()
    by_version = {}
    errors = []

    def writer(wid):
        try:
            handle = cluster.session(
                cache_bytes=0, max_inflight_writes=6
            ).open(blob)
            futures = []
            for i in range(writes_each):
                off = ((wid * writes_each + i) % 64) * PAGE
                fill = wid * writes_each + i + 1
                fut = handle.write_async(page(fill), off)
                futures.append((off, fill, fut))
            for off, fill, fut in futures:
                with log_lock:
                    by_version[fut.result()] = (off, fill)
        except Exception as err:  # pragma: no cover
            errors.append(err)

    threads = [threading.Thread(target=writer, args=(w,)) for w in range(n_writers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    total = n_writers * writes_each
    assert sorted(by_version) == list(range(1, total + 1))  # dense versions
    reader = cluster.session().open(blob)
    assert reader.latest_published() == total
    # every published version equals the prefix-application of patches in
    # version order (global serializability across interleaved async streams)
    oracle = np.zeros(64 * PAGE, np.uint8)
    for v in range(1, total + 1):
        off, fill = by_version[v]
        oracle[off : off + PAGE] = fill
        got = reader.read(0, 64 * PAGE, version=v).data
        np.testing.assert_array_equal(got, oracle, err_msg=f"version {v}")
    cluster.close()


def test_flush_surfaces_async_write_failure():
    sess = make_session(n_data_providers=1, cache_bytes=0)
    handle = sess.create(8 * PAGE, PAGE)
    sess.cluster.provider_manager.fail_provider(0)
    handle.write_async(page(1), 0)
    with pytest.raises(ProviderFailed):
        sess.flush()
    sess.cluster.close()


# ------------------------- failure cleanup ------------------------------------


def test_failed_writev_releases_placements_and_deletes_orphans():
    """Satellite: a mid-writev provider failure with no healthy provider
    left to re-place onto must not leak load credits, stored pages, or
    metadata nodes — and must not wedge publication."""
    # replication 2 over 2 providers: every page holds a ref on BOTH, so
    # when provider 0 dies mid-flight the re-placement has no target left
    sess = make_session(n_data_providers=2, page_replication=2, cache_bytes=0)
    cluster = sess.cluster
    cluster.provider_manager.on_dead = None  # keep the abort path isolated
    handle = sess.create(16 * PAGE, PAGE)
    baseline_load = cluster.provider_manager.load_snapshot()
    provider = cluster.provider_manager.get_provider(0)
    real_put = provider.put_pages
    dropping = [True]

    def crashed_put(items):
        if dropping[0]:
            raise ProviderFailed("injected: provider crashed mid-writev")
        return real_put(items)

    provider.put_pages = crashed_put
    with pytest.raises(ProviderFailed):
        # every retry fails, the health machine declares provider 0 dead,
        # and the mid-flight re-placement finds no healthy non-holder
        handle.write(page(1, 8 * PAGE), 0)
    assert cluster.provider_manager.dead_providers() == [0]
    # placement credits returned
    assert cluster.provider_manager.load_snapshot() == baseline_load
    # orphaned pages deleted from the live providers
    assert all(
        p.n_pages == 0
        for p in cluster.provider_manager.providers()
        if not p.failed
    )
    # metadata nodes of the doomed version dropped
    assert cluster.metadata.total_nodes() == 0
    # the assigned version was withdrawn: nothing wedges, number is reused
    assert cluster.version_manager.assigned_versions(handle.blob_id) == 0
    dropping[0] = False
    cluster.provider_manager.recover_provider(0)  # rejoin: live + placeable
    v = handle.write(page(2, 4 * PAGE), 0)
    assert v == 1
    assert handle.latest_published() == 1
    np.testing.assert_array_equal(
        handle.read(0, 4 * PAGE).data, page(2, 4 * PAGE)
    )
    cluster.close()


def test_abandon_hole_is_skipped_and_rejected():
    """A non-tail abandoned version becomes a publication hole: later
    versions publish over it, readers of it are rejected."""
    vm = VersionManager()
    blob = vm.alloc(8, PAGE)
    v1, _ = vm.assign_version(blob, 0, 2)
    v2, _ = vm.assign_version(blob, 4, 2)  # concurrent writer landed after
    vm.abandon(blob, [v1])  # v1's writer died -> hole, v2 still weaves to it
    assert vm.report_success(blob, v2) == 2  # publication passed the hole
    assert vm.latest_published(blob) == 2
    with pytest.raises(ValueError, match="abandoned"):
        vm.resolve_read_version(blob, v1)
    # the tail case fully erases: numbers are reused
    v3, _ = vm.assign_version(blob, 0, 1)
    vm.abandon(blob, [v3])
    assert vm.assigned_versions(blob) == 2
    v3b, _ = vm.assign_version(blob, 2, 1)
    assert v3b == v3


def test_recover_replays_abandon_entries():
    vm = VersionManager()
    blob = vm.alloc(8, PAGE)
    vm.assign_version(blob, 0, 2)  # v1
    vm.assign_version(blob, 4, 2)  # v2
    vm.abandon(blob, [1])  # hole
    vm.report_success(blob, 2)
    vm2, orphans = VersionManager.recover(vm.journal)
    assert vm2.latest_published(blob) == 2
    assert orphans[blob] == []  # the abandoned version is resolved, not orphaned
    with pytest.raises(ValueError, match="abandoned"):
        vm2.resolve_read_version(blob, 1)


# ------------------------- write traffic accounting ---------------------------


def test_per_destination_write_bytes_recorded():
    sess = make_session(cache_bytes=0)
    handle = sess.create(16 * PAGE, PAGE)
    handle.write(page(1, 8 * PAGE), 0)
    stats = sess.cluster.stats
    wbytes = stats.write_bytes_snapshot()
    assert sum(wbytes.values()) == 8 * PAGE
    # the per-session ledger carries the same signal
    assert sess.stats.write_bytes_snapshot() == wbytes
    rbytes_before = dict(stats.read_bytes_snapshot())
    handle.read(0, 8 * PAGE)
    # reads do not pollute the write-skew signal and vice versa
    assert stats.write_bytes_snapshot() == wbytes
    assert sum(stats.read_bytes_snapshot().values()) > sum(
        rbytes_before.values()
    )
    sess.cluster.close()


# ------------------------- sync/pipelined equivalence -------------------------


def test_sync_and_pipelined_writes_are_semantically_identical():
    a = make_session(cache_bytes=0, sync_write=False)
    b = make_session(cache_bytes=0, sync_write=True)
    ha, hb = a.create(16 * PAGE, PAGE), b.create(16 * PAGE, PAGE)
    patches = [(0, page(1, 2 * PAGE)), (4 * PAGE, page(2, PAGE)),
               (2 * PAGE, page(3, 4 * PAGE))]
    assert ha.writev(patches) == hb.writev(patches)
    for v in (1, 2, 3):
        np.testing.assert_array_equal(
            ha.read(0, 16 * PAGE, version=v).data,
            hb.read(0, 16 * PAGE, version=v).data,
        )
    a.cluster.close()
    b.cluster.close()


# ------------------------------ compare tool ----------------------------------


def test_benchmark_compare_diffs_rows():
    from benchmarks.compare import diff_rows

    old = {"git_rev": "aaa", "rows": [
        {"mode": "write", "clients": 16, "aggregate_MBps": 10.0},
        {"mode": "gone", "clients": 16, "aggregate_MBps": 5.0},
    ]}
    new = {"git_rev": "bbb", "rows": [
        {"mode": "write", "clients": 16, "aggregate_MBps": 15.0},
        {"mode": "stream-write", "clients": 16, "aggregate_MBps": 30.0},
    ]}
    lines = diff_rows(old, new)
    joined = "\n".join(lines)
    assert "write,16,10.0,15.0,+50.0%" in joined
    # a mode added since the previous payload reports "new", never a crash
    assert "stream-write,16,-,30.0,new" in joined
    assert "gone,16,5.0,-,removed" in joined


def test_benchmark_compare_tolerates_malformed_rows():
    """Rows missing keys (older payload schemas) must degrade to '?' cells,
    not crash the trajectory report."""
    from benchmarks.compare import diff_rows

    old = {"git_rev": "aaa", "rows": [
        {"mode": "write", "clients": 16},  # no aggregate_MBps recorded
    ]}
    new = {"git_rev": "bbb", "rows": [
        {"mode": "write", "clients": 16, "aggregate_MBps": 15.0},
        {"mode": "multi-session", "clients": 16, "aggregate_MBps": 99.0},
    ]}
    joined = "\n".join(diff_rows(old, new))
    assert "write,16,?,15.0,?" in joined
    assert "multi-session,16,-,99.0,new" in joined
