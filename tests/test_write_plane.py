"""Pipelined asynchronous write plane + zero-copy transport tests.

Covers the overlapped ``writev`` pipeline (data puts in flight while versions
are assigned and metadata is woven), the bounded ``write_async``/``flush``
window, zero-copy page hand-off on both the write and read paths, write-through
caching, failure cleanup (placement release + orphan deletion + version
abandonment), and the per-destination write-byte accounting.
"""

import threading

import numpy as np
import pytest

from repro.core import BlobStore, ProviderFailed, VersionManager
from repro.core.provider import DataProvider

PAGE = 64


def make_store(**kw):
    kw.setdefault("n_data_providers", 4)
    kw.setdefault("n_metadata_providers", 4)
    return BlobStore(**kw)


def page(fill, nbytes=PAGE):
    return np.full(nbytes, fill, np.uint8)


# ------------------------------ zero-copy -------------------------------------


def test_writev_stores_zero_copy_views_and_freezes_source():
    """No per-page ``.copy()`` on the hot path: providers hold views of the
    writer's buffer, and the buffer is frozen so they can never change."""
    store = make_store(n_data_providers=1, cache_bytes=0)
    blob = store.alloc(8 * PAGE, PAGE)
    buf = np.arange(4 * PAGE, dtype=np.uint8)
    store.write(blob, buf, 0)
    provider = store.provider_manager.get_provider(0)
    stored = [provider.get_page(k) for k in range(4)]
    for pg in stored:
        assert np.shares_memory(pg, buf)  # view, not copy
        assert not pg.flags.writeable
    with pytest.raises(ValueError):
        buf[0] = 99  # the source was surrendered to the store
    store.close()


def test_writev_copies_unfreezable_views_once():
    """A view of a larger writable array cannot be protected by freezing
    (writes through the base would mutate the stored pages), so the write
    plane must fall back to a bulk copy — published data stays immutable."""
    store = make_store(n_data_providers=1)
    blob = store.alloc(8 * PAGE, PAGE)
    big = np.zeros(2 * PAGE, np.uint8)
    v = store.write(blob, big[:PAGE], 0)
    big[0] = 99  # caller mutates the base AFTER publication
    assert store.read(blob, v, 0, PAGE).data[0] == 0  # snapshot unharmed
    store.close()


def test_buffer_surrender_semantics_on_failure():
    """Validation errors must not freeze anything (no side effects before
    the patch list checks out); once the pipeline launches, the buffer is
    surrendered for good even if the write fails — another overlapping
    write may already hold zero-copy views of the same memory, so an abort
    cannot safely hand writability back."""
    store = make_store(cache_bytes=0)
    blob = store.alloc(8 * PAGE, PAGE)
    buf = np.zeros(4 * PAGE, np.uint8)
    with pytest.raises(ValueError, match="page-aligned"):
        store.writev(blob, [(0, buf), (3, page(1))])
    buf[0] = 1  # a rejected batch froze nothing
    for pid in range(4):
        store.provider_manager.fail_provider(pid)
    with pytest.raises(ProviderFailed):
        store.write(blob, buf, 0)
    assert not buf.flags.writeable  # launched pipeline -> surrendered
    store.close()


def test_abort_leaks_hole_version_wreckage_for_later_readers():
    """When a failed writer's version becomes a publication HOLE (a
    concurrent writer was assigned after it), the abort must NOT scrub its
    stored metadata/pages: the later writer's published tree border-links
    into them."""
    store = make_store(n_data_providers=2, cache_bytes=0)
    blob = store.alloc(8 * PAGE, PAGE)
    started, release = _blocking_provider(store, 0)
    failed = []

    def writer_a():
        try:
            store.write(blob, page(1), 0)  # page 0 -> provider 0 (blocked)
        except ProviderFailed as err:
            failed.append(err)

    t = threading.Thread(target=writer_a)
    t.start()
    assert started.wait(10)
    for _ in range(200):  # wait until A holds v1
        if store.version_manager.assigned_versions(blob) == 1:
            break
        threading.Event().wait(0.01)
    v2 = store.write(blob, page(2), PAGE)  # B -> provider 1, assigned after A
    assert v2 == 2
    store.provider_manager.fail_provider(0)
    release.set()
    t.join(10)
    assert failed  # A's data put raised and its writev aborted
    # v1 is a hole: publication passed it, B's version is readable
    assert store.version_manager.latest_published(blob) == 2
    # A's metadata (stored mid-pipeline) survives the abort — B's tree
    # border-links into version 1 for the untouched ranges
    from repro.core import NodeKey
    leaked = dict(store.metadata.iter_nodes(blob))
    assert NodeKey(blob, 1, 0, 1) in leaked
    # B's own data is readable; A's page is genuinely lost (never stored),
    # which is writer-recovery territory — but the metadata spine is intact
    np.testing.assert_array_equal(
        store.read(blob, v2, PAGE, PAGE).data, page(2)
    )
    store.close()


def test_sync_write_baseline_copies_pages():
    """The pre-pipeline A/B baseline keeps its defensive per-page copies."""
    store = make_store(n_data_providers=1, cache_bytes=0, sync_write=True)
    blob = store.alloc(8 * PAGE, PAGE)
    buf = np.arange(2 * PAGE, dtype=np.uint8)
    store.write(blob, buf, 0)
    provider = store.provider_manager.get_provider(0)
    assert not any(np.shares_memory(provider.get_page(k), buf) for k in range(2))
    store.close()


def test_full_page_read_is_zero_copy_view():
    """A read of exactly one whole page returns the stored/cached page itself
    (read-only), not a per-page Python assembly into a fresh buffer."""
    store = make_store()
    blob = store.alloc(8 * PAGE, PAGE)
    store.write(blob, np.arange(8 * PAGE, dtype=np.uint8), 0)
    a = store.read(blob, None, 2 * PAGE, PAGE).data
    b = store.read(blob, None, 2 * PAGE, PAGE).data
    assert np.shares_memory(a, b)  # both are views of the same cached page
    assert not a.flags.writeable
    # unaligned / multi-page segments still assemble into a fresh buffer
    c = store.read(blob, None, 2 * PAGE + 1, PAGE).data
    assert not np.shares_memory(a, c)
    store.close()


def test_full_page_read_of_zero_page_shares_the_zero_buffer():
    store = make_store()
    blob = store.alloc(8 * PAGE, PAGE)
    a = store.read(blob, None, 0, PAGE).data
    b = store.read(blob, None, PAGE, PAGE).data
    assert np.shares_memory(a, b)  # one shared immutable zero page
    assert not a.any()


# --------------------------- write-through cache ------------------------------


def test_write_through_makes_own_rereads_free():
    store = make_store()
    blob = store.alloc(8 * PAGE, PAGE)
    v = store.write(blob, np.arange(4 * PAGE, dtype=np.uint8), 0)
    store.stats.reset()
    got = store.read(blob, v, 0, 4 * PAGE).data
    np.testing.assert_array_equal(got, np.arange(4 * PAGE, dtype=np.uint8))
    assert store.stats.data_rounds == 0  # no provider round-trips
    assert store.stats.metadata_rounds == 0  # no tree traversal either
    assert store.stats.cache_hits == 4
    store.close()


# ----------------------------- pipelining -------------------------------------


def _blocking_provider(store, pid):
    """Make provider ``pid``'s put_pages block until released; returns
    (started, release) events."""
    provider = store.provider_manager.get_provider(pid)
    started, release = threading.Event(), threading.Event()
    real_put = provider.put_pages

    def blocked_put(items):
        started.set()
        assert release.wait(10), "test released too late"
        return real_put(items)

    provider.put_pages = blocked_put
    return started, release


def test_pipelined_writev_overlaps_version_and_metadata_with_data_puts():
    """The tentpole property, asserted structurally: while the data puts are
    still in flight, the version is already assigned AND the metadata nodes
    are already stored. Only report_success waits for the join."""
    store = make_store(n_data_providers=1, cache_bytes=0)
    blob = store.alloc(8 * PAGE, PAGE)
    started, release = _blocking_provider(store, 0)
    done = []
    t = threading.Thread(
        target=lambda: done.append(store.write(blob, page(7, 2 * PAGE), 0))
    )
    t.start()
    try:
        assert started.wait(10)
        # data put is blocked right now, yet the pipeline has moved on:
        vm = store.version_manager
        deadline = threading.Event()
        for _ in range(200):
            if vm.assigned_versions(blob) == 1 and store.metadata.total_nodes() > 0:
                break
            deadline.wait(0.01)
        assert vm.assigned_versions(blob) == 1  # version assigned mid-put
        assert store.metadata.total_nodes() > 0  # metadata stored mid-put
        assert vm.latest_published(blob) == 0  # but success awaits the join
    finally:
        release.set()
        t.join()
    assert done == [1]
    assert store.version_manager.latest_published(blob) == 1
    store.close()


def test_sync_write_keeps_the_stage_barrier():
    """A/B contrast: with sync_write=True no version is assigned until the
    data puts complete (the pre-pipeline full barrier)."""
    store = make_store(n_data_providers=1, cache_bytes=0, sync_write=True)
    blob = store.alloc(8 * PAGE, PAGE)
    started, release = _blocking_provider(store, 0)
    t = threading.Thread(target=lambda: store.write(blob, page(7), 0))
    t.start()
    try:
        assert started.wait(10)
        threading.Event().wait(0.05)  # give a broken pipeline time to leak
        assert store.version_manager.assigned_versions(blob) == 0
        assert store.metadata.total_nodes() == 0
    finally:
        release.set()
        t.join()
    assert store.version_manager.latest_published(blob) == 1
    store.close()


# ------------------------- write_async / flush --------------------------------


def test_write_async_window_applies_backpressure():
    store = make_store(n_data_providers=1, cache_bytes=0, max_inflight_writes=2)
    blob = store.alloc(16 * PAGE, PAGE)
    started, release = _blocking_provider(store, 0)
    f1 = store.write_async(blob, page(1), 0)
    f2 = store.write_async(blob, page(2), PAGE)  # window now full
    assert started.wait(10)
    third_submitted = threading.Event()

    def third():
        store.write_async(blob, page(3), 2 * PAGE)
        third_submitted.set()

    t = threading.Thread(target=third)
    t.start()
    assert not third_submitted.wait(0.1)  # blocked on the window
    release.set()
    t.join(10)
    assert third_submitted.is_set()
    assert sorted([f1.result(), f2.result()]) == [1, 2]
    flushed = store.flush()  # completed-and-pruned writes are not re-reported
    assert 3 in flushed and set(flushed) <= {1, 2, 3}
    assert store.version_manager.latest_published(blob) == 3
    store.close()


def test_write_async_publishes_in_assignment_order_under_random_service():
    """Satellite: versions publish in assignment order per blob even when
    later writes' data lands first (randomized provider service times)."""
    store = make_store(
        n_data_providers=6, n_metadata_providers=6, max_workers=24,
        cache_bytes=0, max_inflight_writes=6,
    )
    rng = np.random.default_rng(7)
    for provider in store.provider_manager.providers():
        provider.page_service_seconds = float(rng.uniform(0.0, 0.004))
    blob = store.alloc(64 * PAGE, PAGE)
    n_writers, writes_each = 3, 8
    log_lock = threading.Lock()
    by_version = {}
    errors = []

    def writer(wid):
        try:
            futures = []
            for i in range(writes_each):
                off = ((wid * writes_each + i) % 64) * PAGE
                fill = wid * writes_each + i + 1
                fut = store.write_async(blob, page(fill), off)
                futures.append((off, fill, fut))
            for off, fill, fut in futures:
                with log_lock:
                    by_version[fut.result()] = (off, fill)
        except Exception as err:  # pragma: no cover
            errors.append(err)

    threads = [threading.Thread(target=writer, args=(w,)) for w in range(n_writers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    total = n_writers * writes_each
    assert sorted(by_version) == list(range(1, total + 1))  # dense versions
    assert store.version_manager.latest_published(blob) == total
    # every published version equals the prefix-application of patches in
    # version order (global serializability across interleaved async streams)
    oracle = np.zeros(64 * PAGE, np.uint8)
    for v in range(1, total + 1):
        off, fill = by_version[v]
        oracle[off : off + PAGE] = fill
        got = store.read(blob, v, 0, 64 * PAGE).data
        np.testing.assert_array_equal(got, oracle, err_msg=f"version {v}")
    store.close()


def test_flush_surfaces_async_write_failure():
    store = make_store(n_data_providers=1, cache_bytes=0)
    blob = store.alloc(8 * PAGE, PAGE)
    store.provider_manager.fail_provider(0)
    store.write_async(blob, page(1), 0)
    with pytest.raises(ProviderFailed):
        store.flush()
    store.close()


# ------------------------- failure cleanup ------------------------------------


def test_failed_writev_releases_placements_and_deletes_orphans():
    """Satellite: a mid-writev provider failure must not leak load credits,
    stored pages, or metadata nodes — and must not wedge publication."""
    store = make_store(cache_bytes=0)
    blob = store.alloc(16 * PAGE, PAGE)
    baseline_load = store.provider_manager.load_snapshot()
    store.provider_manager.fail_provider(2)
    with pytest.raises(ProviderFailed):
        # 8 pages over 4 providers: the failed one is guaranteed a batch
        store.write(blob, page(1, 8 * PAGE), 0)
    # placement credits returned
    assert store.provider_manager.load_snapshot() == baseline_load
    # orphaned pages deleted from the live providers
    assert all(
        p.n_pages == 0
        for p in store.provider_manager.providers()
        if not p.failed
    )
    # metadata nodes of the doomed version dropped
    assert store.metadata.total_nodes() == 0
    # the assigned version was withdrawn: nothing wedges, number is reused
    assert store.version_manager.assigned_versions(blob) == 0
    store.provider_manager.recover_provider(2)
    v = store.write(blob, page(2, 4 * PAGE), 0)
    assert v == 1
    assert store.version_manager.latest_published(blob) == 1
    np.testing.assert_array_equal(
        store.read(blob, None, 0, 4 * PAGE).data, page(2, 4 * PAGE)
    )
    store.close()


def test_abandon_hole_is_skipped_and_rejected():
    """A non-tail abandoned version becomes a publication hole: later
    versions publish over it, readers of it are rejected."""
    vm = VersionManager()
    blob = vm.alloc(8, PAGE)
    v1, _ = vm.assign_version(blob, 0, 2)
    v2, _ = vm.assign_version(blob, 4, 2)  # concurrent writer landed after
    vm.abandon(blob, [v1])  # v1's writer died -> hole, v2 still weaves to it
    assert vm.report_success(blob, v2) == 2  # publication passed the hole
    assert vm.latest_published(blob) == 2
    with pytest.raises(ValueError, match="abandoned"):
        vm.resolve_read_version(blob, v1)
    # the tail case fully erases: numbers are reused
    v3, _ = vm.assign_version(blob, 0, 1)
    vm.abandon(blob, [v3])
    assert vm.assigned_versions(blob) == 2
    v3b, _ = vm.assign_version(blob, 2, 1)
    assert v3b == v3


def test_recover_replays_abandon_entries():
    vm = VersionManager()
    blob = vm.alloc(8, PAGE)
    vm.assign_version(blob, 0, 2)  # v1
    vm.assign_version(blob, 4, 2)  # v2
    vm.abandon(blob, [1])  # hole
    vm.report_success(blob, 2)
    vm2, orphans = VersionManager.recover(vm.journal)
    assert vm2.latest_published(blob) == 2
    assert orphans[blob] == []  # the abandoned version is resolved, not orphaned
    with pytest.raises(ValueError, match="abandoned"):
        vm2.resolve_read_version(blob, 1)


# ------------------------- write traffic accounting ---------------------------


def test_per_destination_write_bytes_recorded():
    store = make_store(cache_bytes=0)
    blob = store.alloc(16 * PAGE, PAGE)
    store.write(blob, page(1, 8 * PAGE), 0)
    wbytes = store.stats.write_bytes_snapshot()
    assert sum(wbytes.values()) == 8 * PAGE
    rbytes_before = dict(store.stats.read_bytes_snapshot())
    store.read(blob, None, 0, 8 * PAGE)
    # reads do not pollute the write-skew signal and vice versa
    assert store.stats.write_bytes_snapshot() == wbytes
    assert sum(store.stats.read_bytes_snapshot().values()) > sum(
        rbytes_before.values()
    )
    store.close()


# ------------------------- sync/pipelined equivalence -------------------------


def test_sync_and_pipelined_writes_are_semantically_identical():
    a = make_store(cache_bytes=0, sync_write=False)
    b = make_store(cache_bytes=0, sync_write=True)
    blob_a, blob_b = a.alloc(16 * PAGE, PAGE), b.alloc(16 * PAGE, PAGE)
    patches = [(0, page(1, 2 * PAGE)), (4 * PAGE, page(2, PAGE)),
               (2 * PAGE, page(3, 4 * PAGE))]
    assert a.writev(blob_a, patches) == b.writev(blob_b, patches)
    for v in (1, 2, 3):
        np.testing.assert_array_equal(
            a.read(blob_a, v, 0, 16 * PAGE).data,
            b.read(blob_b, v, 0, 16 * PAGE).data,
        )
    a.close()
    b.close()


# ------------------------------ compare tool ----------------------------------


def test_benchmark_compare_diffs_rows():
    from benchmarks.compare import diff_rows

    old = {"git_rev": "aaa", "rows": [
        {"mode": "write", "clients": 16, "aggregate_MBps": 10.0},
        {"mode": "gone", "clients": 16, "aggregate_MBps": 5.0},
    ]}
    new = {"git_rev": "bbb", "rows": [
        {"mode": "write", "clients": 16, "aggregate_MBps": 15.0},
        {"mode": "stream-write", "clients": 16, "aggregate_MBps": 30.0},
    ]}
    lines = diff_rows(old, new)
    joined = "\n".join(lines)
    assert "write,16,10.0,15.0,+50.0%" in joined
    assert "stream-write,16,-,30.0,added" in joined
    assert "gone,16,5.0,-,removed" in joined
