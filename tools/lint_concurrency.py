#!/usr/bin/env python
"""Concurrency-discipline lint CLI.

Usage::

    python tools/lint_concurrency.py src/repro [more paths...]

Exits 0 when clean, 1 when any violation is found. See
``repro.analysis.lint`` for the rule set and the ``# lint: allow(rule)``
suppression pragma, and ``repro.analysis.lock_order`` for the declared lock
hierarchy the ``lock-order`` rule enforces.
"""

from __future__ import annotations

import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

from repro.analysis.lint import RULES, lint_paths  # noqa: E402


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        description="static lock-discipline lint for the repro codebase")
    parser.add_argument("paths", nargs="+",
                        help="files or directories to lint")
    parser.add_argument("--rule", action="append", default=None,
                        choices=sorted(RULES),
                        help="only report these rules (repeatable)")
    args = parser.parse_args(argv)

    violations = lint_paths(args.paths)
    if args.rule:
        wanted = set(args.rule)
        violations = [v for v in violations if v.rule in wanted]
    for v in violations:
        print(v)
    if violations:
        print(f"\n{len(violations)} violation(s)")
        return 1
    print("clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
